//! The open-loop serving experiment: rate × scenario × policy under
//! Poisson offered load.
//!
//! The paper's SLO claims are about latency under *offered load*, but the
//! closed-loop grids (fig5..fig9) admit the next query only when a slot
//! frees — queueing delay is structurally invisible there. This sweep
//! replays each dynamic scenario under open-loop Poisson arrivals at
//! several fractions of the pipeline's interference-free peak rate, for
//! ODIN / LLS / static, and reports the full offered-load picture per
//! cell: end-to-end latency (p50/p99), the queued-vs-service split, shed
//! arrivals at the bounded queue, and achieved throughput. Like every
//! figure artifact, the emitted `openloop.json` is byte-stable and
//! `--jobs`-invariant.

use crate::database::synth::synthesize;
use crate::database::TimingDb;
use crate::interference::dynamic::{DynamicScenario, ScenarioAxis};
use crate::json::Value;
use crate::models;
use crate::serving::Workload;
use crate::simulator::{Policy, SimResult};
use crate::util::error::Result;
use crate::util::stats::percentile;

use super::dynamic::run_scenario_workload;
use super::{ExpCtx, Output};

/// Scenarios of the sweep (a subset of the builtins keeps `experiment
/// all` interactive; `odin simulate --scenario X --workload ...` covers
/// the rest ad hoc).
pub const OPENLOOP_SCENARIOS: [&str; 2] = ["burst", "arrivals"];
/// Offered load as fractions of the interference-free peak rate: under,
/// near, and past saturation.
pub const OPENLOOP_RATES: [f64; 3] = [0.6, 0.9, 1.2];
/// Policies per cell (oracle excluded: its zero-cost trials make
/// open-loop queueing comparisons misleading).
pub const OPENLOOP_POLICIES: [Policy; 3] =
    [Policy::Odin { alpha: 2 }, Policy::Lls, Policy::Static];
/// Bound of the arrival queue: small enough that the past-saturation
/// rate visibly sheds.
pub const OPENLOOP_QUEUE_CAP: usize = 64;
/// The model the sweep runs on.
pub const OPENLOOP_MODEL: &str = "vgg16";

/// Headline numbers of one (scenario, rate, policy) cell.
fn cell_json(rate_frac: f64, rate_qps: f64, policy: Policy, r: &SimResult) -> Value {
    let q_mean = r.queued.iter().sum::<f64>() / r.queued.len().max(1) as f64;
    let lat_mean =
        r.latencies.iter().sum::<f64>() / r.latencies.len().max(1) as f64;
    Value::obj(vec![
        ("dropped", Value::from(r.dropped_at.len())),
        ("lat_mean", Value::from(lat_mean)),
        ("lat_p50", Value::from(percentile(&r.latencies, 50.0))),
        ("lat_p99", Value::from(percentile(&r.latencies, 99.0))),
        ("offered", Value::from(r.offered)),
        ("policy", Value::from(policy.label())),
        ("queued_mean", Value::from(q_mean)),
        ("queued_p99", Value::from(percentile(&r.queued, 99.0))),
        ("rate_frac", Value::from(rate_frac)),
        ("rate_qps", Value::from(rate_qps)),
        ("rebalances", Value::from(r.rebalances.len())),
        ("served", Value::from(r.latencies.len())),
        ("service_mean", Value::from(lat_mean - q_mean)),
        ("tput_achieved", Value::from(r.achieved_throughput())),
    ])
}

/// How many queries one openloop cell runs: the scenario horizon for
/// query-axis scenarios (the two are pinned there), and the context's
/// query budget for wall-clock (`"unit": "ms"`) scenarios — whose
/// horizon is *time*, not queries. This is the ROADMAP follow-up fix:
/// the sweep used to pass `scenario.num_queries` unconditionally, which
/// read an ms horizon as a query count and broke ms-axis cells.
pub fn cell_queries(scenario: &DynamicScenario, ctx_queries: usize) -> usize {
    match scenario.axis {
        ScenarioAxis::Queries => scenario.num_queries,
        ScenarioAxis::Millis => ctx_queries,
    }
}

/// One rate row of a scenario sweep: `(rate_frac, rate_qps, per-policy
/// results)`.
pub type RateRow = (f64, f64, Vec<SimResult>);

/// Run the rate sweep of one scenario: for each fraction of `peak`, a
/// seeded Poisson workload replayed for every policy under the identical
/// schedule. Axis-aware via [`cell_queries`], so wall-clock scenarios
/// keep their era boundaries fixed in virtual time at every offered
/// rate.
pub fn sweep_scenario(
    db: &TimingDb,
    scenario: &DynamicScenario,
    peak: f64,
    seed: u64,
    ctx_queries: usize,
    jobs: usize,
) -> Result<Vec<RateRow>> {
    let queries = cell_queries(scenario, ctx_queries);
    let mut out = Vec::with_capacity(OPENLOOP_RATES.len());
    for rate_frac in OPENLOOP_RATES {
        let rate_qps = rate_frac * peak;
        let workload = Workload::poisson(rate_qps, seed)?;
        let (_, results) = run_scenario_workload(
            db,
            scenario,
            &OPENLOOP_POLICIES,
            &workload,
            queries,
            OPENLOOP_QUEUE_CAP,
            jobs,
        )?;
        out.push((rate_frac, rate_qps, results));
    }
    Ok(out)
}

pub fn run(ctx: &ExpCtx) -> Result<()> {
    let mut out = Output::new(ctx, "openloop")?;
    out.line("# openloop — Poisson offered load vs closed-loop-invisible queueing");
    out.line(format!(
        "# rates as fractions of the interference-free peak; queue cap \
         {OPENLOOP_QUEUE_CAP}; seeded arrivals shared by every policy"
    ));
    let spec = models::build(OPENLOOP_MODEL, ctx.spatial).unwrap();
    let db = synthesize(&spec, ctx.seed);
    out.line(format!(
        "{:<10} {:>5} {:<9} {:>9} {:>9} {:>9} {:>8} {:>7} {:>7}",
        "scenario", "rate", "policy", "lat_ms", "p99_ms", "queue_ms", "tput", "drop", "rebal"
    ));
    let mut scenario_vals = Vec::with_capacity(OPENLOOP_SCENARIOS.len());
    for name in OPENLOOP_SCENARIOS {
        let scenario =
            crate::interference::dynamic::builtin(name)?.scaled(ctx.queries)?;
        // the peak rate is a property of the db + EP count, identical for
        // every cell of this scenario
        let peak = {
            let clean = vec![0usize; scenario.num_eps];
            let (_, bottleneck) = crate::coordinator::optimal_config(
                &db,
                &clean,
                scenario.num_eps,
            );
            1.0 / bottleneck
        };
        let mut rate_vals = Vec::with_capacity(OPENLOOP_RATES.len());
        for (rate_frac, rate_qps, results) in
            sweep_scenario(&db, &scenario, peak, ctx.seed, ctx.queries, ctx.jobs)?
        {
            let workload = Workload::poisson(rate_qps, ctx.seed)?;
            let mut cells = Vec::with_capacity(OPENLOOP_POLICIES.len());
            for (policy, r) in OPENLOOP_POLICIES.iter().zip(&results) {
                let v = cell_json(rate_frac, rate_qps, *policy, r);
                out.line(format!(
                    "{:<10} {:>5.2} {:<9} {:>9.2} {:>9.2} {:>9.2} {:>8.2} {:>7} {:>7}",
                    name,
                    rate_frac,
                    policy.label(),
                    v.get("lat_mean").as_f64().unwrap_or(0.0) * 1e3,
                    v.get("lat_p99").as_f64().unwrap_or(0.0) * 1e3,
                    v.get("queued_mean").as_f64().unwrap_or(0.0) * 1e3,
                    v.get("tput_achieved").as_f64().unwrap_or(0.0),
                    v.get("dropped").as_usize().unwrap_or(0),
                    v.get("rebalances").as_usize().unwrap_or(0),
                ));
                cells.push(v);
            }
            rate_vals.push(Value::obj(vec![
                ("cells", Value::arr(cells)),
                ("rate_frac", Value::from(rate_frac)),
                ("rate_qps", Value::from(rate_qps)),
                ("workload", Value::from(workload.spec())),
            ]));
        }
        scenario_vals.push(Value::obj(vec![
            ("name", Value::from(name)),
            ("peak_qps", Value::from(peak)),
            ("queries", Value::from(cell_queries(&scenario, ctx.queries))),
            ("rates", Value::arr(rate_vals)),
        ]));
    }
    if let Some(dir) = &ctx.out_dir {
        let doc = Value::obj(vec![
            ("model", Value::from(OPENLOOP_MODEL)),
            ("queue_cap", Value::from(OPENLOOP_QUEUE_CAP)),
            ("scenarios", Value::arr(scenario_vals)),
        ]);
        let path = dir.join("openloop.json");
        crate::json::write_file(&path, &doc)?;
        println!("# wrote {}", path.display());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interference::dynamic::builtin;
    use crate::json::to_string_pretty;

    #[test]
    fn openloop_sweep_is_jobs_invariant_and_queues_past_saturation() {
        let spec = models::build(OPENLOOP_MODEL, 64).unwrap();
        let db = synthesize(&spec, 42);
        let scenario = builtin("burst").unwrap().scaled(400).unwrap();
        let peak = {
            let (_, b) =
                crate::coordinator::optimal_config(&db, &vec![0usize; 4], 4);
            1.0 / b
        };
        let w = Workload::poisson(1.2 * peak, 42).unwrap();
        let run = |jobs| {
            let (_, results) = run_scenario_workload(
                &db,
                &scenario,
                &OPENLOOP_POLICIES,
                &w,
                400,
                OPENLOOP_QUEUE_CAP,
                jobs,
            )
            .unwrap();
            results
        };
        let serial = run(1);
        let parallel = run(3);
        for ((a, b), p) in serial.iter().zip(&parallel).zip(&OPENLOOP_POLICIES) {
            assert_eq!(
                to_string_pretty(&cell_json(1.2, 1.2 * peak, *p, a)),
                to_string_pretty(&cell_json(1.2, 1.2 * peak, *p, b)),
                "{} cell differs across --jobs",
                p.label()
            );
        }
        // past saturation the static pipeline must visibly queue
        let st = serial.last().unwrap();
        let q_mean: f64 =
            st.queued.iter().sum::<f64>() / st.queued.len() as f64;
        assert!(q_mean > 0.0, "no queueing at 1.2x peak");
    }

    #[test]
    fn ms_axis_cells_keep_era_boundaries_rate_independent() {
        // the ROADMAP follow-up regression: a wall-clock scenario through
        // the openloop cell path must start its stressor era at the same
        // *virtual time* at every offered rate — the sweep used to pin
        // the query axis, which made the ms horizon unusable as a cell
        let spec = models::build(OPENLOOP_MODEL, 64).unwrap();
        let db = synthesize(&spec, 42);
        let scenario = DynamicScenario::from_json_str(
            r#"{"name": "ms-cell", "eps": 4, "unit": "ms",
                "horizon_ms": 20000,
                "phases": [{"kind": "task", "start": 2000, "end": 20000,
                            "ep": 1, "scenario": 9}]}"#,
        )
        .unwrap();
        assert_eq!(cell_queries(&scenario, 400), 400, "ms horizon leaked");
        let peak = {
            let (_, b) =
                crate::coordinator::optimal_config(&db, &vec![0usize; 4], 4);
            1.0 / b
        };
        let rows =
            sweep_scenario(&db, &scenario, peak, 42, 400, 2).unwrap();
        assert_eq!(rows.len(), OPENLOOP_RATES.len());
        let era_start = |r: &SimResult| {
            let i = r
                .stressed
                .iter()
                .position(|&s| s)
                .expect("run never reached the 2s era");
            r.start_times[i]
        };
        // static policy, slowest vs fastest rate: the era is a wall-clock
        // fact, so both runs cross 2000 ms at (nearly) the same virtual
        // time even though their arrival indexes differ
        let slow = era_start(rows.first().unwrap().2.last().unwrap());
        let fast = era_start(rows.last().unwrap().2.last().unwrap());
        assert!(
            (slow - fast).abs() < 0.3,
            "era start moved with the rate: {slow:.3}s vs {fast:.3}s"
        );
        assert!(
            (1.8..2.5).contains(&slow),
            "era did not start near 2.0s: {slow:.3}s"
        );
        // and a query-axis builtin still pins the cell to its horizon
        let q = builtin("burst").unwrap().scaled(300).unwrap();
        assert_eq!(cell_queries(&q, 999), 300);
    }
}
