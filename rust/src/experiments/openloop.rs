//! The open-loop serving experiment: rate × scenario × policy under
//! Poisson offered load.
//!
//! The paper's SLO claims are about latency under *offered load*, but the
//! closed-loop grids (fig5..fig9) admit the next query only when a slot
//! frees — queueing delay is structurally invisible there. This sweep
//! replays each dynamic scenario under open-loop Poisson arrivals at
//! several fractions of the pipeline's interference-free peak rate, for
//! ODIN / LLS / static, and reports the full offered-load picture per
//! cell: end-to-end latency (p50/p99), the queued-vs-service split, shed
//! arrivals at the bounded queue, and achieved throughput. Like every
//! figure artifact, the emitted `openloop.json` is byte-stable and
//! `--jobs`-invariant.

use crate::database::synth::synthesize;
use crate::json::Value;
use crate::models;
use crate::serving::Workload;
use crate::simulator::{Policy, SimResult};
use crate::util::error::Result;
use crate::util::stats::percentile;

use super::dynamic::run_scenario_workload;
use super::{ExpCtx, Output};

/// Scenarios of the sweep (a subset of the builtins keeps `experiment
/// all` interactive; `odin simulate --scenario X --workload ...` covers
/// the rest ad hoc).
pub const OPENLOOP_SCENARIOS: [&str; 2] = ["burst", "arrivals"];
/// Offered load as fractions of the interference-free peak rate: under,
/// near, and past saturation.
pub const OPENLOOP_RATES: [f64; 3] = [0.6, 0.9, 1.2];
/// Policies per cell (oracle excluded: its zero-cost trials make
/// open-loop queueing comparisons misleading).
pub const OPENLOOP_POLICIES: [Policy; 3] =
    [Policy::Odin { alpha: 2 }, Policy::Lls, Policy::Static];
/// Bound of the arrival queue: small enough that the past-saturation
/// rate visibly sheds.
pub const OPENLOOP_QUEUE_CAP: usize = 64;
/// The model the sweep runs on.
pub const OPENLOOP_MODEL: &str = "vgg16";

/// Headline numbers of one (scenario, rate, policy) cell.
fn cell_json(rate_frac: f64, rate_qps: f64, policy: Policy, r: &SimResult) -> Value {
    let q_mean = r.queued.iter().sum::<f64>() / r.queued.len().max(1) as f64;
    let lat_mean =
        r.latencies.iter().sum::<f64>() / r.latencies.len().max(1) as f64;
    Value::obj(vec![
        ("dropped", Value::from(r.dropped_at.len())),
        ("lat_mean", Value::from(lat_mean)),
        ("lat_p50", Value::from(percentile(&r.latencies, 50.0))),
        ("lat_p99", Value::from(percentile(&r.latencies, 99.0))),
        ("offered", Value::from(r.offered)),
        ("policy", Value::from(policy.label())),
        ("queued_mean", Value::from(q_mean)),
        ("queued_p99", Value::from(percentile(&r.queued, 99.0))),
        ("rate_frac", Value::from(rate_frac)),
        ("rate_qps", Value::from(rate_qps)),
        ("rebalances", Value::from(r.rebalances.len())),
        ("served", Value::from(r.latencies.len())),
        ("service_mean", Value::from(lat_mean - q_mean)),
        ("tput_achieved", Value::from(r.achieved_throughput())),
    ])
}

pub fn run(ctx: &ExpCtx) -> Result<()> {
    let mut out = Output::new(ctx, "openloop")?;
    out.line("# openloop — Poisson offered load vs closed-loop-invisible queueing");
    out.line(format!(
        "# rates as fractions of the interference-free peak; queue cap \
         {OPENLOOP_QUEUE_CAP}; seeded arrivals shared by every policy"
    ));
    let spec = models::build(OPENLOOP_MODEL, ctx.spatial).unwrap();
    let db = synthesize(&spec, ctx.seed);
    out.line(format!(
        "{:<10} {:>5} {:<9} {:>9} {:>9} {:>9} {:>8} {:>7} {:>7}",
        "scenario", "rate", "policy", "lat_ms", "p99_ms", "queue_ms", "tput", "drop", "rebal"
    ));
    let mut scenario_vals = Vec::with_capacity(OPENLOOP_SCENARIOS.len());
    for name in OPENLOOP_SCENARIOS {
        let scenario =
            crate::interference::dynamic::builtin(name)?.scaled(ctx.queries)?;
        // the peak rate is a property of the db + EP count, identical for
        // every cell of this scenario
        let peak = {
            let clean = vec![0usize; scenario.num_eps];
            let (_, bottleneck) = crate::coordinator::optimal_config(
                &db,
                &clean,
                scenario.num_eps,
            );
            1.0 / bottleneck
        };
        let mut rate_vals = Vec::with_capacity(OPENLOOP_RATES.len());
        for rate_frac in OPENLOOP_RATES {
            let rate_qps = rate_frac * peak;
            let workload = Workload::poisson(rate_qps, ctx.seed)?;
            let (_, results) = run_scenario_workload(
                &db,
                &scenario,
                &OPENLOOP_POLICIES,
                &workload,
                scenario.num_queries,
                OPENLOOP_QUEUE_CAP,
                ctx.jobs,
            )?;
            let mut cells = Vec::with_capacity(OPENLOOP_POLICIES.len());
            for (policy, r) in OPENLOOP_POLICIES.iter().zip(&results) {
                let v = cell_json(rate_frac, rate_qps, *policy, r);
                out.line(format!(
                    "{:<10} {:>5.2} {:<9} {:>9.2} {:>9.2} {:>9.2} {:>8.2} {:>7} {:>7}",
                    name,
                    rate_frac,
                    policy.label(),
                    v.get("lat_mean").as_f64().unwrap_or(0.0) * 1e3,
                    v.get("lat_p99").as_f64().unwrap_or(0.0) * 1e3,
                    v.get("queued_mean").as_f64().unwrap_or(0.0) * 1e3,
                    v.get("tput_achieved").as_f64().unwrap_or(0.0),
                    v.get("dropped").as_usize().unwrap_or(0),
                    v.get("rebalances").as_usize().unwrap_or(0),
                ));
                cells.push(v);
            }
            rate_vals.push(Value::obj(vec![
                ("cells", Value::arr(cells)),
                ("rate_frac", Value::from(rate_frac)),
                ("rate_qps", Value::from(rate_qps)),
                ("workload", Value::from(workload.spec())),
            ]));
        }
        scenario_vals.push(Value::obj(vec![
            ("name", Value::from(name)),
            ("peak_qps", Value::from(peak)),
            ("queries", Value::from(scenario.num_queries)),
            ("rates", Value::arr(rate_vals)),
        ]));
    }
    if let Some(dir) = &ctx.out_dir {
        let doc = Value::obj(vec![
            ("model", Value::from(OPENLOOP_MODEL)),
            ("queue_cap", Value::from(OPENLOOP_QUEUE_CAP)),
            ("scenarios", Value::arr(scenario_vals)),
        ]);
        let path = dir.join("openloop.json");
        crate::json::write_file(&path, &doc)?;
        println!("# wrote {}", path.display());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interference::dynamic::builtin;
    use crate::json::to_string_pretty;

    #[test]
    fn openloop_sweep_is_jobs_invariant_and_queues_past_saturation() {
        let spec = models::build(OPENLOOP_MODEL, 64).unwrap();
        let db = synthesize(&spec, 42);
        let scenario = builtin("burst").unwrap().scaled(400).unwrap();
        let peak = {
            let (_, b) =
                crate::coordinator::optimal_config(&db, &vec![0usize; 4], 4);
            1.0 / b
        };
        let w = Workload::poisson(1.2 * peak, 42).unwrap();
        let run = |jobs| {
            let (_, results) = run_scenario_workload(
                &db,
                &scenario,
                &OPENLOOP_POLICIES,
                &w,
                400,
                OPENLOOP_QUEUE_CAP,
                jobs,
            )
            .unwrap();
            results
        };
        let serial = run(1);
        let parallel = run(3);
        for ((a, b), p) in serial.iter().zip(&parallel).zip(&OPENLOOP_POLICIES) {
            assert_eq!(
                to_string_pretty(&cell_json(1.2, 1.2 * peak, *p, a)),
                to_string_pretty(&cell_json(1.2, 1.2 * peak, *p, b)),
                "{} cell differs across --jobs",
                p.label()
            );
        }
        // past saturation the static pipeline must visibly queue
        let st = serial.last().unwrap();
        let q_mean: f64 =
            st.queued.iter().sum::<f64>() / st.queued.len() as f64;
        assert!(q_mean > 0.0, "no queueing at 1.2x peak");
    }
}
