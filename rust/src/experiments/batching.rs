//! The deadline-aware batching experiment: rate × scenario × batch
//! policy under open-loop Poisson offered load.
//!
//! The open-loop sweep (`openloop`) showed that past-saturation offered
//! load queues and sheds on the one-query-per-traversal path. This sweep
//! measures what the [`crate::serving::BatchFormer`] buys back: for each
//! dynamic scenario and offered-rate fraction, the same seeded arrival
//! stream runs under `off` (the historical admission, bit for bit),
//! `fixed:4`, and `deadline` batch policies on a static pipeline (no
//! rebalancing — the knee belongs to the batching axis alone). Per cell
//! it reports the latency/throughput knee — end-to-end p50/p99, achieved
//! throughput, traversal counts, mean batch size — plus the per-window
//! timeline rows (with the `batches`/`mean_batch` schema columns), and
//! the fraction of windows whose p99 clears the deadline the former
//! budgets against. Like every figure artifact, the emitted
//! `batching.json` is byte-stable and `--jobs`-invariant.

use crate::database::synth::synthesize;
use crate::database::TimingDb;
use crate::interference::dynamic::DynamicScenario;
use crate::json::Value;
use crate::models;
use crate::serving::{BatchPolicy, Workload, BATCH_SLACK_FACTOR};
use crate::simulator::window::{window_metrics, windows_json, DEFAULT_WINDOW};
use crate::simulator::{Policy, SimConfig, SimResult};
use crate::util::error::Result;
use crate::util::stats::percentile;

use super::openloop::cell_queries;
use super::{ExpCtx, Output};

/// Scenarios of the sweep (the open-loop pair: one interference burst,
/// one arrival-driven scenario).
pub const BATCHING_SCENARIOS: [&str; 2] = ["burst", "arrivals"];
/// Offered load as fractions of the interference-free peak rate.
pub const BATCHING_RATES: [f64; 3] = [0.6, 0.9, 1.2];
/// Batch policies per cell: the historical path, a fixed cap, and the
/// deadline-aware former.
pub const BATCHING_POLICIES: [BatchPolicy; 3] =
    [BatchPolicy::Off, BatchPolicy::Fixed(4), BatchPolicy::Deadline];
/// Bound of the arrival queue (matches the open-loop sweep).
pub const BATCHING_QUEUE_CAP: usize = 64;
/// The model the sweep runs on.
pub const BATCHING_MODEL: &str = "vgg16";

/// The deadline (seconds past arrival) every query of a batching cell
/// carries — the same slack rule the engine stamps on simulated
/// arrivals: `BATCH_SLACK_FACTOR ×` the clean serial latency of the
/// initial configuration.
pub fn cell_deadline_s(db: &TimingDb, num_eps: usize) -> f64 {
    let clean = vec![0usize; num_eps];
    let (config, _) = crate::coordinator::optimal_config(db, &clean, num_eps);
    let serial: f64 =
        crate::pipeline::stage_times(&config, db, &clean).iter().sum();
    BATCH_SLACK_FACTOR * serial
}

/// Headline numbers of one (scenario, rate, batch-policy) cell, windows
/// included.
pub fn cell_json(
    rate_frac: f64,
    rate_qps: f64,
    batch: BatchPolicy,
    deadline_s: f64,
    r: &SimResult,
    schedule: &crate::interference::Schedule,
) -> Value {
    let served = r.latencies.len();
    let q_mean = r.queued.iter().sum::<f64>() / served.max(1) as f64;
    let lat_mean = r.latencies.iter().sum::<f64>() / served.max(1) as f64;
    let traversals: f64 = r.batch.iter().map(|&b| 1.0 / b as f64).sum();
    let ws = window_metrics(r, schedule, DEFAULT_WINDOW, 0.7);
    // the SLO verdict of the knee: windows whose end-to-end p99 clears
    // the deadline the former budgets against
    let ok = ws
        .iter()
        .filter(|w| {
            percentile(&r.latencies[w.start..w.end], 99.0) <= deadline_s
        })
        .count();
    let win_p99_ok_frac = ok as f64 / ws.len().max(1) as f64;
    Value::obj(vec![
        ("batch", Value::from(batch.spec())),
        ("batches", Value::from(traversals.round() as usize)),
        ("deadline_s", Value::from(deadline_s)),
        ("dropped", Value::from(r.dropped_at.len())),
        ("lat_mean", Value::from(lat_mean)),
        ("lat_p50", Value::from(percentile(&r.latencies, 50.0))),
        ("lat_p99", Value::from(percentile(&r.latencies, 99.0))),
        (
            "mean_batch",
            Value::from(served as f64 / traversals.max(1e-12)),
        ),
        ("offered", Value::from(r.offered)),
        ("queued_mean", Value::from(q_mean)),
        ("rate_frac", Value::from(rate_frac)),
        ("rate_qps", Value::from(rate_qps)),
        ("served", Value::from(served)),
        ("tput_achieved", Value::from(r.achieved_throughput())),
        ("win_p99_ok_frac", Value::from(win_p99_ok_frac)),
        ("windows", windows_json(&ws)),
    ])
}

/// One rate row of a scenario sweep: `(rate_frac, rate_qps, per-batch-
/// policy results)`, results ordered as [`BATCHING_POLICIES`].
pub type BatchRateRow = (f64, f64, Vec<SimResult>);

/// Run the batching rate sweep of one scenario: for each fraction of
/// `peak`, a seeded Poisson workload replayed for every batch policy on
/// a static pipeline under the identical schedule.
pub fn sweep_scenario(
    db: &TimingDb,
    scenario: &DynamicScenario,
    peak: f64,
    seed: u64,
    ctx_queries: usize,
    jobs: usize,
) -> Result<Vec<BatchRateRow>> {
    let queries = cell_queries(scenario, ctx_queries);
    let schedule = scenario.compile();
    let cfgs: Vec<SimConfig> = BATCHING_POLICIES
        .iter()
        .map(|&bp| {
            SimConfig::new(scenario.num_eps, Policy::Static)
                .with_window(DEFAULT_WINDOW)
                .with_queue_cap(BATCHING_QUEUE_CAP)
                .with_batch(bp)
        })
        .collect();
    let mut out = Vec::with_capacity(BATCHING_RATES.len());
    for rate_frac in BATCHING_RATES {
        let rate_qps = rate_frac * peak;
        let workload = Workload::poisson(rate_qps, seed)?;
        let results = crate::simulator::engine::simulate_policies_workload(
            db,
            &schedule,
            scenario.axis,
            &cfgs,
            &workload,
            queries,
            jobs,
        )?;
        out.push((rate_frac, rate_qps, results));
    }
    Ok(out)
}

pub fn run(ctx: &ExpCtx) -> Result<()> {
    let mut out = Output::new(ctx, "batching")?;
    out.line("# batching — deadline-aware batch forming vs offered load");
    out.line(format!(
        "# static pipeline, queue cap {BATCHING_QUEUE_CAP}; rates as \
         fractions of the interference-free peak; seeded arrivals shared \
         by every batch policy"
    ));
    let spec = models::build(BATCHING_MODEL, ctx.spatial).unwrap();
    let db = synthesize(&spec, ctx.seed);
    out.line(format!(
        "{:<10} {:>5} {:<10} {:>9} {:>9} {:>8} {:>6} {:>6} {:>7}",
        "scenario", "rate", "batch", "lat_ms", "p99_ms", "tput", "mean_b",
        "drop", "p99_ok"
    ));
    let mut scenario_vals = Vec::with_capacity(BATCHING_SCENARIOS.len());
    for name in BATCHING_SCENARIOS {
        let scenario =
            crate::interference::dynamic::builtin(name)?.scaled(ctx.queries)?;
        let schedule = scenario.compile();
        let peak = {
            let clean = vec![0usize; scenario.num_eps];
            let (_, bottleneck) = crate::coordinator::optimal_config(
                &db,
                &clean,
                scenario.num_eps,
            );
            1.0 / bottleneck
        };
        let deadline_s = cell_deadline_s(&db, scenario.num_eps);
        let mut rate_vals = Vec::with_capacity(BATCHING_RATES.len());
        for (rate_frac, rate_qps, results) in
            sweep_scenario(&db, &scenario, peak, ctx.seed, ctx.queries, ctx.jobs)?
        {
            let workload = Workload::poisson(rate_qps, ctx.seed)?;
            let mut cells = Vec::with_capacity(BATCHING_POLICIES.len());
            for (bp, r) in BATCHING_POLICIES.iter().zip(&results) {
                let v = cell_json(
                    rate_frac, rate_qps, *bp, deadline_s, r, &schedule,
                );
                out.line(format!(
                    "{:<10} {:>5.2} {:<10} {:>9.2} {:>9.2} {:>8.2} {:>6.2} {:>6} {:>7.2}",
                    name,
                    rate_frac,
                    bp.spec(),
                    v.get("lat_mean").as_f64().unwrap_or(0.0) * 1e3,
                    v.get("lat_p99").as_f64().unwrap_or(0.0) * 1e3,
                    v.get("tput_achieved").as_f64().unwrap_or(0.0),
                    v.get("mean_batch").as_f64().unwrap_or(0.0),
                    v.get("dropped").as_usize().unwrap_or(0),
                    v.get("win_p99_ok_frac").as_f64().unwrap_or(0.0),
                ));
                cells.push(v);
            }
            rate_vals.push(Value::obj(vec![
                ("cells", Value::arr(cells)),
                ("rate_frac", Value::from(rate_frac)),
                ("rate_qps", Value::from(rate_qps)),
                ("workload", Value::from(workload.spec())),
            ]));
        }
        scenario_vals.push(Value::obj(vec![
            ("deadline_s", Value::from(deadline_s)),
            ("name", Value::from(name)),
            ("peak_qps", Value::from(peak)),
            ("queries", Value::from(cell_queries(&scenario, ctx.queries))),
            ("rates", Value::arr(rate_vals)),
        ]));
    }
    if let Some(dir) = &ctx.out_dir {
        let doc = Value::obj(vec![
            ("model", Value::from(BATCHING_MODEL)),
            ("policy", Value::from(Policy::Static.label())),
            ("queue_cap", Value::from(BATCHING_QUEUE_CAP)),
            ("scenarios", Value::arr(scenario_vals)),
            ("slack_factor", Value::from(BATCH_SLACK_FACTOR)),
        ]);
        let path = dir.join("batching.json");
        crate::json::write_file(&path, &doc)?;
        println!("# wrote {}", path.display());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interference::dynamic::builtin;
    use crate::json::to_string_pretty;

    #[test]
    fn batching_sweep_is_jobs_invariant() {
        let spec = models::build(BATCHING_MODEL, 64).unwrap();
        let db = synthesize(&spec, 42);
        let scenario = builtin("burst").unwrap().scaled(400).unwrap();
        let schedule = scenario.compile();
        let peak = {
            let (_, b) =
                crate::coordinator::optimal_config(&db, &vec![0usize; 4], 4);
            1.0 / b
        };
        let deadline_s = cell_deadline_s(&db, 4);
        let serial = sweep_scenario(&db, &scenario, peak, 42, 400, 1).unwrap();
        let parallel = sweep_scenario(&db, &scenario, peak, 42, 400, 3).unwrap();
        for ((rf, rq, a), (_, _, b)) in serial.iter().zip(&parallel) {
            for ((ra, rb), bp) in a.iter().zip(b).zip(&BATCHING_POLICIES) {
                assert_eq!(
                    to_string_pretty(&cell_json(
                        *rf, *rq, *bp, deadline_s, ra, &schedule
                    )),
                    to_string_pretty(&cell_json(
                        *rf, *rq, *bp, deadline_s, rb, &schedule
                    )),
                    "{} cell at {rf}x differs across --jobs",
                    bp.spec()
                );
            }
        }
    }

    #[test]
    fn deadline_batching_beats_off_past_saturation_under_burst() {
        // the acceptance knee: at 1.2x peak offered under the burst
        // scenario, the deadline former must sustain >= 1.5x the
        // throughput of the one-query-per-traversal path while the
        // per-window p99 clears the deadline in >= 80% of windows
        let spec = models::build(BATCHING_MODEL, 64).unwrap();
        let db = synthesize(&spec, 42);
        let scenario = builtin("burst").unwrap().scaled(800).unwrap();
        let schedule = scenario.compile();
        let peak = {
            let (_, b) =
                crate::coordinator::optimal_config(&db, &vec![0usize; 4], 4);
            1.0 / b
        };
        let deadline_s = cell_deadline_s(&db, 4);
        let rows = sweep_scenario(&db, &scenario, peak, 42, 800, 2).unwrap();
        let (rf, _, results) = rows.last().unwrap();
        assert_eq!(*rf, 1.2);
        let off = &results[0];
        let deadline = &results[2];
        let ratio =
            deadline.achieved_throughput() / off.achieved_throughput();
        assert!(
            ratio >= 1.5,
            "deadline/off throughput ratio {ratio:.2} under 1.2x burst"
        );
        let ws = window_metrics(deadline, &schedule, DEFAULT_WINDOW, 0.7);
        let ok = ws
            .iter()
            .filter(|w| {
                percentile(&deadline.latencies[w.start..w.end], 99.0)
                    <= deadline_s
            })
            .count();
        let frac = ok as f64 / ws.len() as f64;
        assert!(frac >= 0.8, "p99 cleared the deadline in {frac:.2} of windows");
        // the deadline former genuinely batches past saturation
        assert!(deadline.batch.iter().any(|&b| b > 1));
        // and fixed:4 stays within its cap
        assert!(results[1].batch.iter().all(|&b| b <= 4));
    }
}
