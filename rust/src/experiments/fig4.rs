//! Fig 4 — performance impact of the 12 colocation scenarios on a single
//! VGG16 layer (we use conv3_1, a mid-network conv, as the paper's
//! representative layer).
//!
//! Prints the synthetic database's slowdowns; when a measured database
//! (`odin bench-db`) exists at artifacts/db_measured.json, prints it side
//! by side.

use crate::util::error::Result;

use crate::database::{synth::synthesize, TimingDb};
use crate::interference::{catalogue, NUM_SCENARIOS};
use crate::models;

use super::{ExpCtx, Output};

const LAYER: usize = 4; // conv3_1

pub fn run(ctx: &ExpCtx) -> Result<()> {
    let mut out = Output::new(ctx, "fig4")?;
    let spec = models::vgg16(ctx.spatial);
    let db = synthesize(&spec, ctx.seed);
    let measured = TimingDb::load("artifacts/db_measured.json").ok();

    out.line(format!(
        "# Fig 4 — slowdown of VGG16 layer '{}' under each scenario",
        db.unit_names[LAYER]
    ));
    out.line("# paper shape: same-core scenarios harsher than same-socket;");
    out.line("#   more stressor threads => larger slowdown; membw hurts convs less");
    out.line(format!(
        "{:<4} {:<16} {:>11} {:>12}",
        "id", "scenario", "synthetic", "measured"
    ));
    for s in catalogue() {
        let syn = db.time(LAYER, s.id) / db.base_time(LAYER);
        let mea = measured
            .as_ref()
            .map(|m| format!("{:.2}x", m.time(LAYER, s.id) / m.base_time(LAYER)))
            .unwrap_or_else(|| "-".into());
        out.line(format!(
            "{:<4} {:<16} {:>10.2}x {:>12}",
            s.id,
            s.label(),
            syn,
            mea
        ));
    }
    // bar sketch of the synthetic slowdowns
    let max = (1..=NUM_SCENARIOS)
        .map(|s| db.time(LAYER, s) / db.base_time(LAYER))
        .fold(1.0f64, f64::max);
    out.line("#");
    for s in catalogue() {
        let v = db.time(LAYER, s.id) / db.base_time(LAYER);
        let bars = ((v - 1.0) / (max - 1.0) * 40.0).round() as usize;
        out.line(format!("# {:>2} |{}", s.id, "#".repeat(bars)));
    }
    Ok(())
}
