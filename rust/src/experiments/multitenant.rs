//! The multi-tenant SLO experiment: tenant-mix × scenario × policy under
//! the SLO-aware queue.
//!
//! ODIN's opening claim is "inference as a service" — co-located tenants
//! with different latency targets sharing one pipeline — but every other
//! sweep serves a single anonymous stream. This sweep replays each
//! builtin tenant set (rates pinned to fractions of the pipeline's
//! interference-free peak) under dynamic scenarios, for ODIN / LLS /
//! static, and reports the per-tenant ledger each cell produces:
//! offered / completed / dropped / SLO violations, the queued-vs-service
//! split, and each tenant's achieved completion share against its
//! weight share (the fairness reference). Like every figure artifact,
//! `multitenant.json` is byte-stable and `--jobs`-invariant.

use crate::database::synth::synthesize;
use crate::database::TimingDb;
use crate::interference::dynamic::{DynamicScenario, ScenarioAxis};
use crate::interference::Schedule;
use crate::json::Value;
use crate::models;
use crate::serving::tenant::{self, tally, totals_json, Fairness, TenantSet};
use crate::simulator::window::{attach_tenant_windows, window_metrics, windows_json};
use crate::simulator::{simulate_tenants_policies, MtSimResult, Policy, SimConfig};
use crate::util::error::Result;

use super::dynamic::{DYN_SLO_LEVEL, DYN_WINDOW};
use super::{ExpCtx, Output};

/// Scenarios of the sweep (subset of the builtins, like `openloop`).
pub const MT_SCENARIOS: [&str; 2] = ["burst", "arrivals"];
/// Builtin tenant mixes swept (the `mixed` set rides along in the CLI).
pub const MT_SETS: [&str; 2] = ["tiers", "even"];
/// Total offered load as fractions of the interference-free peak rate.
pub const MT_RATE_FRACS: [f64; 2] = [0.8, 1.2];
/// Policies per cell.
pub const MT_POLICIES: [Policy; 3] =
    [Policy::Odin { alpha: 2 }, Policy::Lls, Policy::Static];
/// Bound of the SLO-aware arrival queue.
pub const MT_QUEUE_CAP: usize = 64;
/// The model the sweep runs on.
pub const MT_MODEL: &str = "vgg16";
/// Fairness axis of the enforcement section: the same cell under the
/// report-only queue, WFQ/DRR admission, and WFQ plus occupancy caps.
pub const MT_FAIRNESS: [Fairness; 3] =
    [Fairness::Reported, Fairness::Wfq, Fairness::WfqCaps];
/// The enforcement section's fixed cell: the `mixed` set (steady
/// double-weight interactive tenant vs a spiky batch tenant in one SLA
/// class) on `burst` at 1.2x peak under ODIN — the regime where
/// report-only admission degenerates to arrival order and the burst
/// crowds the interactive tenant out.
pub const MT_FAIRNESS_SET: &str = "mixed";
pub const MT_FAIRNESS_SCENARIO: &str = "burst";
pub const MT_FAIRNESS_RATE_FRAC: f64 = 1.2;
pub const MT_FAIRNESS_POLICY: Policy = Policy::Odin { alpha: 2 };

/// Run `policies` against one scenario under one tenant set: identical
/// schedule, identical merged arrival stream, SLO-aware queue bounded at
/// `queue_cap` holding tenants to their weights per `fairness`
/// ([`Fairness::Reported`] = the historical report-only queue, bit for
/// bit). Shared by this experiment and `odin simulate --tenants`.
pub fn run_tenant_scenario(
    db: &TimingDb,
    scenario: &DynamicScenario,
    tenants: &TenantSet,
    policies: &[Policy],
    queue_cap: usize,
    fairness: Fairness,
    queries: usize,
    jobs: usize,
) -> Result<(Schedule, Vec<MtSimResult>)> {
    let schedule = scenario.compile();
    let cfgs: Vec<SimConfig> = policies
        .iter()
        .map(|&p| {
            SimConfig::new(scenario.num_eps, p)
                .with_window(DYN_WINDOW)
                .with_queue_cap(queue_cap)
                .with_fairness(fairness)
        })
        .collect();
    let results = simulate_tenants_policies(
        db,
        &schedule,
        scenario.axis,
        &cfgs,
        tenants,
        queries,
        jobs,
    )?;
    Ok((schedule, results))
}

/// Byte-stable document for one (scenario, tenant set) run: per-policy
/// per-tenant totals (the [`totals_json`] schema shared with
/// `live_*.json`) plus per-window timelines whose rows carry the
/// `tenants` array — the simulator half of the live-vs-sim schema
/// contract.
pub fn mt_scenario_json(
    scenario: &DynamicScenario,
    schedule: &Schedule,
    tenants: &TenantSet,
    policies: &[Policy],
    results: &[MtSimResult],
) -> Value {
    assert_eq!(policies.len(), results.len());
    let ids = tenants.ids();
    let mut policy_vals = Vec::with_capacity(policies.len());
    for (policy, r) in policies.iter().zip(results) {
        let mut ws =
            window_metrics(&r.result, schedule, DYN_WINDOW, DYN_SLO_LEVEL);
        attach_tenant_windows(
            &mut ws,
            &ids,
            &r.tenant,
            &r.blown,
            &r.result.queued,
            &r.result.latencies,
            &r.result.dropped_at,
            &r.dropped_tenant,
        );
        let totals = tally(
            tenants,
            &r.tenant,
            &r.blown,
            &r.result.queued,
            &r.result.latencies,
            &r.dropped_tenant,
        );
        let blown_total = r.blown.iter().filter(|&&b| b).count();
        let lat_mean = r.result.latencies.iter().sum::<f64>()
            / r.result.latencies.len().max(1) as f64;
        policy_vals.push(Value::obj(vec![
            ("completed", Value::from(r.result.latencies.len())),
            ("dropped", Value::from(r.result.dropped_at.len())),
            ("lat_mean", Value::from(lat_mean)),
            ("offered", Value::from(r.result.offered)),
            ("policy", Value::from(policy.label())),
            ("rebalances", Value::from(r.result.rebalances.len())),
            ("slo_violations", Value::from(blown_total)),
            ("tenants", totals_json(&totals)),
            ("windows", windows_json(&ws)),
        ]));
    }
    Value::obj(vec![
        ("eps", Value::from(scenario.num_eps)),
        ("name", Value::from(scenario.name.clone())),
        ("policies", Value::arr(policy_vals)),
        ("queries", Value::from(scenario.num_queries)),
        (
            "summary",
            Value::obj(vec![(
                "interference_load",
                Value::from(schedule.interference_load()),
            )]),
        ),
        ("tenant_set", Value::from(tenants.name.clone())),
    ])
}

/// The shared key/value pairs of one sweep cell (totals only — the full
/// window timelines live in the CLI's per-run documents).
fn cell_pairs(
    policy: Policy,
    tenants: &TenantSet,
    r: &MtSimResult,
) -> Vec<(&'static str, Value)> {
    let totals = tally(
        tenants,
        &r.tenant,
        &r.blown,
        &r.result.queued,
        &r.result.latencies,
        &r.dropped_tenant,
    );
    // the fairness check comes from the same shares() the emitted
    // per-tenant columns use, so the summary cannot drift from them
    let unfairness = tenant::unfairness(&totals);
    let blown_total = r.blown.iter().filter(|&&b| b).count();
    vec![
        ("completed", Value::from(r.result.latencies.len())),
        ("dropped", Value::from(r.result.dropped_at.len())),
        ("offered", Value::from(r.result.offered)),
        ("policy", Value::from(policy.label())),
        ("rebalances", Value::from(r.result.rebalances.len())),
        ("slo_violations", Value::from(blown_total)),
        ("tenants", totals_json(&totals)),
        ("unfairness", Value::from(unfairness)),
    ]
}

/// Compact per-cell JSON for the sweep artifact — the historical 8-key
/// schema, untouched by the fairness section.
fn cell_json(policy: Policy, tenants: &TenantSet, r: &MtSimResult) -> Value {
    Value::obj(cell_pairs(policy, tenants, r))
}

/// A fairness-section cell: the same 8 columns plus the `fairness` axis
/// label (keys stay alphabetical for the byte-stable writer).
fn fairness_cell_json(
    fairness: Fairness,
    policy: Policy,
    tenants: &TenantSet,
    r: &MtSimResult,
) -> Value {
    let mut pairs = cell_pairs(policy, tenants, r);
    pairs.insert(2, ("fairness", Value::from(fairness.spec())));
    Value::obj(pairs)
}

pub fn run(ctx: &ExpCtx) -> Result<()> {
    let mut out = Output::new(ctx, "multitenant")?;
    out.line("# multitenant — SLO-aware serving: tenant mix x scenario x policy");
    out.line(format!(
        "# EDF-within-priority admission, deadline-aware shedding, queue \
         cap {MT_QUEUE_CAP};"
    ));
    out.line("# total offered rate pinned to fractions of the clean peak");
    let spec = models::build(MT_MODEL, ctx.spatial).unwrap();
    let db = synthesize(&spec, ctx.seed);
    out.line(format!(
        "{:<7} {:<9} {:>5} {:<9} {:<8} {:>7} {:>6} {:>6} {:>6} {:>9}",
        "set", "scenario", "rate", "policy", "tenant", "offered", "done",
        "drop", "viol", "queued_ms"
    ));
    let mut set_vals = Vec::with_capacity(MT_SETS.len());
    for set_name in MT_SETS {
        let base = tenant::builtin(set_name)?;
        let mut scenario_vals = Vec::with_capacity(MT_SCENARIOS.len());
        for name in MT_SCENARIOS {
            let scenario = crate::interference::dynamic::builtin(name)?
                .scaled(ctx.queries)?;
            let queries = match scenario.axis {
                ScenarioAxis::Queries => scenario.num_queries,
                ScenarioAxis::Millis => ctx.queries,
            };
            let peak = {
                let clean = vec![0usize; scenario.num_eps];
                let (_, bottleneck) = crate::coordinator::optimal_config(
                    &db,
                    &clean,
                    scenario.num_eps,
                );
                1.0 / bottleneck
            };
            let mut rate_vals = Vec::with_capacity(MT_RATE_FRACS.len());
            for rate_frac in MT_RATE_FRACS {
                let total_qps = rate_frac * peak;
                let tenants = base.with_total_rate(total_qps)?;
                let (_, results) = run_tenant_scenario(
                    &db,
                    &scenario,
                    &tenants,
                    &MT_POLICIES,
                    MT_QUEUE_CAP,
                    Fairness::Reported,
                    queries,
                    ctx.jobs,
                )?;
                let mut cells = Vec::with_capacity(MT_POLICIES.len());
                for (policy, r) in MT_POLICIES.iter().zip(&results) {
                    let v = cell_json(*policy, &tenants, r);
                    for t in v.get("tenants").as_arr().unwrap_or(&[]) {
                        out.line(format!(
                            "{:<7} {:<9} {:>5.2} {:<9} {:<8} {:>7} {:>6} \
                             {:>6} {:>6} {:>9.2}",
                            set_name,
                            name,
                            rate_frac,
                            policy.label(),
                            t.get("id").as_str().unwrap_or("?"),
                            t.get("offered").as_usize().unwrap_or(0),
                            t.get("completed").as_usize().unwrap_or(0),
                            t.get("dropped").as_usize().unwrap_or(0),
                            t.get("slo_violations").as_usize().unwrap_or(0),
                            t.get("queued_ns").as_f64().unwrap_or(0.0) / 1e6,
                        ));
                    }
                    cells.push(v);
                }
                rate_vals.push(Value::obj(vec![
                    ("cells", Value::arr(cells)),
                    ("rate_frac", Value::from(rate_frac)),
                    ("total_qps", Value::from(total_qps)),
                ]));
            }
            scenario_vals.push(Value::obj(vec![
                ("name", Value::from(name)),
                ("peak_qps", Value::from(peak)),
                ("queries", Value::from(queries)),
                ("rates", Value::arr(rate_vals)),
            ]));
        }
        set_vals.push(Value::obj(vec![
            ("name", Value::from(set_name)),
            ("scenarios", Value::arr(scenario_vals)),
            (
                "tenants",
                Value::arr(
                    base.tenants
                        .iter()
                        .map(|t| Value::from(t.id.clone()))
                        .collect(),
                ),
            ),
        ]));
    }
    // the enforcement section: one fixed cell swept over the fairness
    // axis — report-only vs WFQ/DRR vs WFQ + occupancy caps, identical
    // stream, identical schedule
    let fairness_val = {
        let scenario = crate::interference::dynamic::builtin(
            MT_FAIRNESS_SCENARIO,
        )?
        .scaled(ctx.queries)?;
        let queries = match scenario.axis {
            ScenarioAxis::Queries => scenario.num_queries,
            ScenarioAxis::Millis => ctx.queries,
        };
        let peak = {
            let clean = vec![0usize; scenario.num_eps];
            let (_, bottleneck) = crate::coordinator::optimal_config(
                &db,
                &clean,
                scenario.num_eps,
            );
            1.0 / bottleneck
        };
        let total_qps = MT_FAIRNESS_RATE_FRAC * peak;
        let tenants = tenant::builtin(MT_FAIRNESS_SET)?
            .with_total_rate(total_qps)?;
        let mut cells = Vec::with_capacity(MT_FAIRNESS.len());
        for fairness in MT_FAIRNESS {
            let (_, results) = run_tenant_scenario(
                &db,
                &scenario,
                &tenants,
                &[MT_FAIRNESS_POLICY],
                MT_QUEUE_CAP,
                fairness,
                queries,
                ctx.jobs,
            )?;
            let v = fairness_cell_json(
                fairness,
                MT_FAIRNESS_POLICY,
                &tenants,
                &results[0],
            );
            out.line(format!(
                "# fairness {:<8} {}@{:.1}x {}: unfairness {:.4}, \
                 completed {}, dropped {}",
                fairness.spec(),
                MT_FAIRNESS_SCENARIO,
                MT_FAIRNESS_RATE_FRAC,
                MT_FAIRNESS_SET,
                v.get("unfairness").as_f64().unwrap_or(-1.0),
                v.get("completed").as_usize().unwrap_or(0),
                v.get("dropped").as_usize().unwrap_or(0),
            ));
            cells.push(v);
        }
        Value::obj(vec![
            ("cells", Value::arr(cells)),
            ("peak_qps", Value::from(peak)),
            ("queries", Value::from(queries)),
            ("rate_frac", Value::from(MT_FAIRNESS_RATE_FRAC)),
            ("scenario", Value::from(MT_FAIRNESS_SCENARIO)),
            ("tenant_set", Value::from(MT_FAIRNESS_SET)),
            ("total_qps", Value::from(total_qps)),
        ])
    };
    if let Some(dir) = &ctx.out_dir {
        let doc = Value::obj(vec![
            ("fairness", fairness_val),
            ("model", Value::from(MT_MODEL)),
            ("queue_cap", Value::from(MT_QUEUE_CAP)),
            ("sets", Value::arr(set_vals)),
            ("slo_level", Value::from(DYN_SLO_LEVEL)),
            ("window", Value::from(DYN_WINDOW)),
        ]);
        let path = dir.join("multitenant.json");
        crate::json::write_file(&path, &doc)?;
        println!("# wrote {}", path.display());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interference::dynamic::builtin;
    use crate::json::to_string_pretty;

    #[test]
    fn mt_scenario_sweep_is_jobs_invariant_and_schema_stable() {
        let spec = models::build(MT_MODEL, 64).unwrap();
        let db = synthesize(&spec, 42);
        let scenario = builtin("burst").unwrap().scaled(400).unwrap();
        let peak = {
            let (_, b) =
                crate::coordinator::optimal_config(&db, &vec![0usize; 4], 4);
            1.0 / b
        };
        let tenants =
            tenant::builtin("tiers").unwrap().with_total_rate(1.2 * peak).unwrap();
        let run = |jobs| {
            let (schedule, results) = run_tenant_scenario(
                &db,
                &scenario,
                &tenants,
                &MT_POLICIES,
                MT_QUEUE_CAP,
                Fairness::Reported,
                400,
                jobs,
            )
            .unwrap();
            to_string_pretty(&mt_scenario_json(
                &scenario,
                &schedule,
                &tenants,
                &MT_POLICIES,
                &results,
            ))
        };
        let a = run(1);
        let b = run(3);
        assert_eq!(a, b, "multi-tenant sweep is not jobs-invariant");
        // schema: every window row carries the tenants array; totals use
        // the shared 13-key schema
        let doc = crate::json::parse(&a).unwrap();
        assert_eq!(doc.get("tenant_set").as_str(), Some("tiers"));
        for p in doc.get("policies").as_arr().unwrap() {
            assert_eq!(p.get("tenants").as_arr().unwrap().len(), 2);
            assert_eq!(p.get("tenants").idx(0).keys().len(), 13);
            for row in p.get("windows").as_arr().unwrap() {
                assert_eq!(row.keys().len(), 17);
                let tr = row.get("tenants").as_arr().unwrap();
                assert_eq!(tr.len(), 2);
                assert_eq!(tr[0].keys().len(), 7);
            }
            // conservation: offered = completed + dropped, overall and
            // per tenant
            let offered = p.get("offered").as_usize().unwrap();
            let completed = p.get("completed").as_usize().unwrap();
            let dropped = p.get("dropped").as_usize().unwrap();
            assert_eq!(offered, completed + dropped);
            for t in p.get("tenants").as_arr().unwrap() {
                assert_eq!(
                    t.get("offered").as_usize().unwrap(),
                    t.get("completed").as_usize().unwrap()
                        + t.get("dropped").as_usize().unwrap()
                );
            }
        }
    }

    #[test]
    fn enforced_fairness_lowers_unfairness_on_the_mixed_burst() {
        // the artifact's acceptance cell: the `mixed` set on `burst` at
        // 1.2x peak under ODIN. Report-only admission degenerates to
        // arrival order (one class, equal deadline offsets), so the
        // batch tenant's sustained 6x burst crowds the double-weight
        // interactive tenant down to its arrival share; WFQ + caps must
        // hold it near its weight share instead — strictly lower
        // unfairness, with the per-tenant ledger conserved in both.
        let spec = models::build(MT_MODEL, 64).unwrap();
        let db = synthesize(&spec, 42);
        let scenario = builtin(MT_FAIRNESS_SCENARIO)
            .unwrap()
            .scaled(600)
            .unwrap();
        let peak = {
            let (_, b) =
                crate::coordinator::optimal_config(&db, &vec![0usize; 4], 4);
            1.0 / b
        };
        let tenants = tenant::builtin(MT_FAIRNESS_SET)
            .unwrap()
            .with_total_rate(MT_FAIRNESS_RATE_FRAC * peak)
            .unwrap();
        let unfairness_of = |fairness: Fairness| {
            let (_, results) = run_tenant_scenario(
                &db,
                &scenario,
                &tenants,
                &[MT_FAIRNESS_POLICY],
                MT_QUEUE_CAP,
                fairness,
                600,
                1,
            )
            .unwrap();
            let r = &results[0];
            assert_eq!(
                r.result.offered,
                r.result.latencies.len() + r.result.dropped_at.len(),
                "{fairness:?}: ledger must conserve offered arrivals"
            );
            let totals = tally(
                &tenants,
                &r.tenant,
                &r.blown,
                &r.result.queued,
                &r.result.latencies,
                &r.dropped_tenant,
            );
            tenant::unfairness(&totals)
        };
        let reported = unfairness_of(Fairness::Reported);
        let capped = unfairness_of(Fairness::WfqCaps);
        assert!(
            capped < reported,
            "wfq+caps must beat report-only on the acceptance cell: \
             got {capped:.4} vs {reported:.4}"
        );
    }

    #[test]
    fn tight_tenant_suffers_more_under_overload() {
        // the tiers set at 1.3x peak: the 60ms gold tenant records SLO
        // violations or sheds while 600ms bronze keeps a lower blow rate
        let spec = models::build(MT_MODEL, 64).unwrap();
        let db = synthesize(&spec, 42);
        let scenario = builtin("burst").unwrap().scaled(600).unwrap();
        let peak = {
            let (_, b) =
                crate::coordinator::optimal_config(&db, &vec![0usize; 4], 4);
            1.0 / b
        };
        let tenants = tenant::builtin("tiers")
            .unwrap()
            .with_total_rate(1.3 * peak)
            .unwrap();
        let (_, results) = run_tenant_scenario(
            &db,
            &scenario,
            &tenants,
            &[Policy::Static],
            32,
            Fairness::Reported,
            600,
            1,
        )
        .unwrap();
        let totals = tally(
            &tenants,
            &results[0].tenant,
            &results[0].blown,
            &results[0].result.queued,
            &results[0].result.latencies,
            &results[0].dropped_tenant,
        );
        let gold = &totals[0];
        assert!(
            gold.slo_violations + gold.dropped > 0,
            "60ms tenant at 1.3x peak never suffered"
        );
    }
}
