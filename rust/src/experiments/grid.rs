//! The shared §4.2 experiment grid behind Figs 5–8: frequency period ×
//! duration ∈ {2, 10, 100}², models {VGG16, ResNet-50}, policies
//! {ODIN α=2, ODIN α=10, LLS}, 4000 queries, 4 EPs.

use anyhow::Result;

use crate::database::synth::synthesize;
use crate::interference::{RandomInterference, Schedule};
use crate::models;
use crate::simulator::{simulate, Policy, SimConfig, SimSummary};

use super::{ExpCtx, Output};

pub const GRID_FREQS: [usize; 3] = [2, 10, 100];
pub const GRID_DURS: [usize; 3] = [2, 10, 100];
pub const GRID_MODELS: [&str; 2] = ["vgg16", "resnet50"];
pub const GRID_POLICIES: [Policy; 3] = [
    Policy::Odin { alpha: 2 },
    Policy::Odin { alpha: 10 },
    Policy::Lls,
];
const NUM_EPS: usize = 4;

#[derive(Clone, Debug)]
pub struct GridCell {
    pub model: &'static str,
    pub policy: Policy,
    pub period: usize,
    pub duration: usize,
}

#[derive(Clone, Debug)]
pub struct GridResult {
    pub cell: GridCell,
    pub summary: SimSummary,
}

pub fn grid_cells() -> Vec<GridCell> {
    let mut out = Vec::new();
    for &model in &GRID_MODELS {
        for &policy in &GRID_POLICIES {
            for &period in &GRID_FREQS {
                for &duration in &GRID_DURS {
                    out.push(GridCell { model, policy, period, duration });
                }
            }
        }
    }
    out
}

/// Run the full grid (all runs share the same interference schedule per
/// (model, period, duration) so policies face identical conditions).
pub fn run_grid(ctx: &ExpCtx) -> Result<Vec<GridResult>> {
    let mut out = Vec::new();
    for &model in &GRID_MODELS {
        let spec = models::build(model, ctx.spatial).unwrap();
        let db = synthesize(&spec, ctx.seed);
        for &period in &GRID_FREQS {
            for &duration in &GRID_DURS {
                let schedule = Schedule::random(
                    NUM_EPS,
                    ctx.queries,
                    RandomInterference {
                        period,
                        duration,
                        seed: ctx.seed ^ (period as u64) << 8 ^ duration as u64,
                        p_active: 1.0,
                    },
                );
                for &policy in &GRID_POLICIES {
                    let r = simulate(
                        &db,
                        &schedule,
                        &SimConfig::new(NUM_EPS, policy),
                    );
                    out.push(GridResult {
                        cell: GridCell { model, policy, period, duration },
                        summary: SimSummary::of(&r),
                    });
                }
            }
        }
    }
    Ok(out)
}

/// Which figure to print from the grid data.
#[derive(Clone, Copy, Debug)]
pub enum Figure {
    /// Fig 5: latency distributions (mean/p50/p99 per cell).
    Latency,
    /// Fig 6: throughput distributions.
    Throughput,
    /// Fig 7: tail-latency (p99) distribution per model/policy.
    TailLatency,
    /// Fig 8: % of time in rebalancing phases.
    Overhead,
}

impl Figure {
    fn id(self) -> &'static str {
        match self {
            Figure::Latency => "fig5",
            Figure::Throughput => "fig6",
            Figure::TailLatency => "fig7",
            Figure::Overhead => "fig8",
        }
    }
}

pub fn run_figure(ctx: &ExpCtx, fig: Figure) -> Result<()> {
    let mut out = Output::new(ctx, fig.id())?;
    let results = run_grid(ctx)?;
    match fig {
        Figure::Latency => {
            out.line("# Fig 5 — end-to-end latency (ms) per [period, duration] cell");
            out.line("# paper shape: ODIN < LLS everywhere; high-frequency short");
            out.line("#   interference is worst; alpha=10 <= alpha=2 latency mostly");
            header(&mut out, "lat_mean  lat_p50   lat_p99");
            for r in &results {
                row(&mut out, r, format!(
                    "{:>8.2}  {:>8.2}  {:>8.2}",
                    r.summary.latency.mean * 1e3,
                    r.summary.latency.p50 * 1e3,
                    r.summary.latency.p99 * 1e3,
                ));
            }
        }
        Figure::Throughput => {
            out.line("# Fig 6 — windowed throughput (q/s) per [period, duration] cell");
            out.line("# paper shape: ODIN >= LLS in most cells; [100,100] comparable;");
            out.line("#   rebalance phases appear as low-throughput outliers (w_min)");
            header(&mut out, "tput_p50  w_p50   w_min  achieved");
            for r in &results {
                row(&mut out, r, format!(
                    "{:>8.2} {:>6.2} {:>7.2}  {:>8.2}",
                    r.summary.throughput.p50,
                    r.summary.windowed.p50,
                    r.summary.windowed.min,
                    r.summary.achieved_throughput,
                ));
            }
        }
        Figure::TailLatency => {
            out.line("# Fig 7 — tail (p99) latency distribution across grid cells (ms)");
            out.line("# paper shape: ODIN tails significantly below LLS; ~14% lower avg");
            for &model in &GRID_MODELS {
                for &policy in &GRID_POLICIES {
                    let tails: Vec<f64> = results
                        .iter()
                        .filter(|r| r.cell.model == model && r.cell.policy == policy)
                        .map(|r| r.summary.tail_latency * 1e3)
                        .collect();
                    let s = crate::util::stats::Summary::of(&tails);
                    out.line(format!(
                        "{model:<9} {:<9} p99 across cells: min={:.2} mean={:.2} max={:.2} ms",
                        policy.label(),
                        s.min,
                        s.mean,
                        s.max
                    ));
                }
            }
        }
        Figure::Overhead => {
            out.line("# Fig 8 — % of time in rebalancing phases per cell");
            out.line("# paper shape: highest at [2,2] (constant re-exploration),");
            out.line("#   decreasing with longer frequency periods and durations");
            header(&mut out, "rebal_%   episodes  serial/episode");
            for r in &results {
                row(&mut out, r, format!(
                    "{:>7.2}%  {:>8}  {:>14.1}",
                    r.summary.rebalance_fraction * 100.0,
                    r.summary.num_rebalances,
                    r.summary.serial_per_rebalance,
                ));
            }
        }
    }
    Ok(())
}

fn header(out: &mut Output, cols: &str) {
    out.line(format!(
        "{:<9} {:<9} {:>6} {:>8}  {cols}",
        "model", "policy", "period", "duration"
    ));
}

fn row(out: &mut Output, r: &GridResult, cols: String) {
    out.line(format!(
        "{:<9} {:<9} {:>6} {:>8}  {cols}",
        r.cell.model,
        r.cell.policy.label(),
        r.cell.period,
        r.cell.duration,
    ));
}
