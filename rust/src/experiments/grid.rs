//! The shared §4.2 experiment grid behind Figs 5–8: frequency period ×
//! duration ∈ {2, 10, 100}², models {VGG16, ResNet-50}, policies
//! {ODIN α=2, ODIN α=10, LLS}, 4000 queries, 4 EPs.
//!
//! The sweep fans out over `ExpCtx::jobs` worker threads, one work item
//! per (model, period, duration) combo so all three policies of a combo
//! share one schedule (identical conditions, as the paper requires).
//! Results merge in the fixed model → period → duration → policy order,
//! so the printed rows and the figure JSON are byte-identical for every
//! `--jobs` value.

use std::sync::Arc;

use crate::database::synth::synthesize;
use crate::database::TimingDb;
use crate::interference::{RandomInterference, Schedule};
use crate::json::Value;
use crate::models;
use crate::simulator::{simulate, Policy, SimConfig, SimSummary};
use crate::util::error::Result;
use crate::util::ThreadPool;

use super::{ExpCtx, Output};

pub const GRID_FREQS: [usize; 3] = [2, 10, 100];
pub const GRID_DURS: [usize; 3] = [2, 10, 100];
pub const GRID_MODELS: [&str; 2] = ["vgg16", "resnet50"];
pub const GRID_POLICIES: [Policy; 3] = [
    Policy::Odin { alpha: 2 },
    Policy::Odin { alpha: 10 },
    Policy::Lls,
];
const NUM_EPS: usize = 4;

#[derive(Clone, Debug)]
pub struct GridCell {
    pub model: &'static str,
    pub policy: Policy,
    pub period: usize,
    pub duration: usize,
}

#[derive(Clone, Debug)]
pub struct GridResult {
    pub cell: GridCell,
    pub summary: SimSummary,
}

pub fn grid_cells() -> Vec<GridCell> {
    let mut out = Vec::new();
    for &model in &GRID_MODELS {
        for &policy in &GRID_POLICIES {
            for &period in &GRID_FREQS {
                for &duration in &GRID_DURS {
                    out.push(GridCell { model, policy, period, duration });
                }
            }
        }
    }
    out
}

/// Run the full grid, fanning combos across `ctx.jobs` threads. All runs
/// of a combo share the same interference schedule so policies face
/// identical conditions; the merge order (and thus every downstream
/// rendering) is independent of `jobs`.
pub fn run_grid(ctx: &ExpCtx) -> Result<Vec<GridResult>> {
    // synthesize each model's database once and share it across the
    // fan-out (it is deterministic in (model, seed), so sharing changes
    // nothing except the redundant work)
    let mut combos = Vec::new();
    for &model in &GRID_MODELS {
        let spec = models::build(model, ctx.spatial).unwrap();
        let db = Arc::new(synthesize(&spec, ctx.seed));
        for &period in &GRID_FREQS {
            for &duration in &GRID_DURS {
                combos.push((model, Arc::clone(&db), period, duration));
            }
        }
    }
    let (seed, queries) = (ctx.seed, ctx.queries);
    type Combo = (&'static str, Arc<TimingDb>, usize, usize);
    let run_combo = move |(model, db, period, duration): Combo| {
        let schedule = Schedule::random(
            NUM_EPS,
            queries,
            RandomInterference {
                period,
                duration,
                seed: seed ^ ((period as u64) << 8) ^ duration as u64,
                p_active: 1.0,
            },
        );
        GRID_POLICIES
            .iter()
            .map(|&policy| {
                let r = simulate(&db, &schedule, &SimConfig::new(NUM_EPS, policy));
                GridResult {
                    cell: GridCell { model, policy, period, duration },
                    summary: SimSummary::of(&r),
                }
            })
            .collect::<Vec<GridResult>>()
    };
    let nested: Vec<Vec<GridResult>> = if ctx.jobs > 1 {
        let pool = ThreadPool::new(ctx.jobs.min(combos.len()));
        pool.map(combos, run_combo)
    } else {
        combos.into_iter().map(run_combo).collect()
    };
    Ok(nested.into_iter().flatten().collect())
}

/// Deterministic JSON rendering of grid results: stable key order
/// (BTreeMap emission) on top of the stable merge order makes the bytes
/// identical across `--jobs` settings.
pub fn grid_results_json(results: &[GridResult]) -> Value {
    Value::arr(
        results
            .iter()
            .map(|r| {
                Value::obj(vec![
                    ("model", Value::from(r.cell.model)),
                    ("policy", Value::from(r.cell.policy.label())),
                    ("period", Value::from(r.cell.period)),
                    ("duration", Value::from(r.cell.duration)),
                    ("lat_mean", Value::from(r.summary.latency.mean)),
                    ("lat_p50", Value::from(r.summary.latency.p50)),
                    ("lat_p99", Value::from(r.summary.latency.p99)),
                    ("tput_mean", Value::from(r.summary.throughput.mean)),
                    ("tput_p50", Value::from(r.summary.throughput.p50)),
                    ("windowed_p50", Value::from(r.summary.windowed.p50)),
                    ("windowed_min", Value::from(r.summary.windowed.min)),
                    ("achieved", Value::from(r.summary.achieved_throughput)),
                    (
                        "rebalance_fraction",
                        Value::from(r.summary.rebalance_fraction),
                    ),
                    ("rebalances", Value::from(r.summary.num_rebalances)),
                    (
                        "serial_per_rebalance",
                        Value::from(r.summary.serial_per_rebalance),
                    ),
                ])
            })
            .collect(),
    )
}

/// Which figure to print from the grid data.
#[derive(Clone, Copy, Debug)]
pub enum Figure {
    /// Fig 5: latency distributions (mean/p50/p99 per cell).
    Latency,
    /// Fig 6: throughput distributions.
    Throughput,
    /// Fig 7: tail-latency (p99) distribution per model/policy.
    TailLatency,
    /// Fig 8: % of time in rebalancing phases.
    Overhead,
}

impl Figure {
    fn id(self) -> &'static str {
        match self {
            Figure::Latency => "fig5",
            Figure::Throughput => "fig6",
            Figure::TailLatency => "fig7",
            Figure::Overhead => "fig8",
        }
    }
}

pub fn run_figure(ctx: &ExpCtx, fig: Figure) -> Result<()> {
    let mut out = Output::new(ctx, fig.id())?;
    let results = run_grid(ctx)?;
    match fig {
        Figure::Latency => {
            out.line("# Fig 5 — end-to-end latency (ms) per [period, duration] cell");
            out.line("# paper shape: ODIN < LLS everywhere; high-frequency short");
            out.line("#   interference is worst; alpha=10 <= alpha=2 latency mostly");
            header(&mut out, "lat_mean  lat_p50   lat_p99");
            for r in &results {
                row(
                    &mut out,
                    r,
                    format!(
                        "{:>8.2}  {:>8.2}  {:>8.2}",
                        r.summary.latency.mean * 1e3,
                        r.summary.latency.p50 * 1e3,
                        r.summary.latency.p99 * 1e3,
                    ),
                );
            }
        }
        Figure::Throughput => {
            out.line("# Fig 6 — windowed throughput (q/s) per [period, duration] cell");
            out.line("# paper shape: ODIN >= LLS in most cells; [100,100] comparable;");
            out.line("#   rebalance phases appear as low-throughput outliers (w_min)");
            header(&mut out, "tput_p50  w_p50   w_min  achieved");
            for r in &results {
                row(
                    &mut out,
                    r,
                    format!(
                        "{:>8.2} {:>6.2} {:>7.2}  {:>8.2}",
                        r.summary.throughput.p50,
                        r.summary.windowed.p50,
                        r.summary.windowed.min,
                        r.summary.achieved_throughput,
                    ),
                );
            }
        }
        Figure::TailLatency => {
            out.line("# Fig 7 — tail (p99) latency distribution across grid cells (ms)");
            out.line("# paper shape: ODIN tails significantly below LLS; ~14% lower avg");
            for &model in &GRID_MODELS {
                for &policy in &GRID_POLICIES {
                    let tails: Vec<f64> = results
                        .iter()
                        .filter(|r| r.cell.model == model && r.cell.policy == policy)
                        .map(|r| r.summary.tail_latency * 1e3)
                        .collect();
                    let s = crate::util::stats::Summary::of(&tails);
                    out.line(format!(
                        "{model:<9} {:<9} p99 across cells: min={:.2} mean={:.2} max={:.2} ms",
                        policy.label(),
                        s.min,
                        s.mean,
                        s.max
                    ));
                }
            }
        }
        Figure::Overhead => {
            out.line("# Fig 8 — % of time in rebalancing phases per cell");
            out.line("# paper shape: highest at [2,2] (constant re-exploration),");
            out.line("#   decreasing with longer frequency periods and durations");
            header(&mut out, "rebal_%   episodes  serial/episode");
            for r in &results {
                row(
                    &mut out,
                    r,
                    format!(
                        "{:>7.2}%  {:>8}  {:>14.1}",
                        r.summary.rebalance_fraction * 100.0,
                        r.summary.num_rebalances,
                        r.summary.serial_per_rebalance,
                    ),
                );
            }
        }
    }
    if let Some(dir) = &ctx.out_dir {
        let path = dir.join(format!("{}.json", fig.id()));
        crate::json::write_file(&path, &grid_results_json(&results))?;
        // stdout only: the .txt mirror must stay byte-identical across
        // output directories and --jobs settings
        println!("# wrote {}", path.display());
    }
    Ok(())
}

fn header(out: &mut Output, cols: &str) {
    out.line(format!(
        "{:<9} {:<9} {:>6} {:>8}  {cols}",
        "model", "policy", "period", "duration"
    ));
}

fn row(out: &mut Output, r: &GridResult, cols: String) {
    out.line(format!(
        "{:<9} {:<9} {:>6} {:>8}  {cols}",
        r.cell.model,
        r.cell.policy.label(),
        r.cell.period,
        r.cell.duration,
    ));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::to_string_pretty;

    fn small_ctx(jobs: usize) -> ExpCtx {
        ExpCtx { queries: 150, jobs, ..ExpCtx::default() }
    }

    #[test]
    fn cells_enumerate_in_declared_order() {
        let cells = grid_cells();
        assert_eq!(
            cells.len(),
            GRID_MODELS.len() * GRID_POLICIES.len() * GRID_FREQS.len() * GRID_DURS.len()
        );
        assert_eq!(cells[0].model, "vgg16");
        assert_eq!(cells[0].period, 2);
    }

    #[test]
    fn parallel_sweep_matches_serial_sweep_bytewise() {
        // the acceptance contract: --jobs 1 and --jobs 4 must produce
        // identical figure JSON, byte for byte
        let a = run_grid(&small_ctx(1)).unwrap();
        let b = run_grid(&small_ctx(4)).unwrap();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.cell.model, y.cell.model);
            assert_eq!(x.cell.policy, y.cell.policy);
            assert_eq!(x.cell.period, y.cell.period);
            assert_eq!(x.cell.duration, y.cell.duration);
        }
        let ja = to_string_pretty(&grid_results_json(&a));
        let jb = to_string_pretty(&grid_results_json(&b));
        assert_eq!(ja, jb);
    }

    #[test]
    fn grid_rows_follow_serial_nesting_order() {
        // parallel merge must reproduce model → period → duration → policy
        let results = run_grid(&small_ctx(3)).unwrap();
        let mut i = 0;
        for &model in &GRID_MODELS {
            for &period in &GRID_FREQS {
                for &duration in &GRID_DURS {
                    for &policy in &GRID_POLICIES {
                        let c = &results[i].cell;
                        assert_eq!((c.model, c.period, c.duration), (model, period, duration));
                        assert_eq!(c.policy, policy);
                        i += 1;
                    }
                }
            }
        }
        assert_eq!(i, results.len());
    }
}
