//! Fig 3 — timeline of a VGG16 pipeline with ODIN reacting to
//! interference arriving at "time steps" 5, 10, 15 and leaving at 20.
//!
//! We map the paper's time steps to query indices (1 step = 20 queries)
//! and print the achieved vs resource-constrained throughput series plus
//! the configuration after each reaction.

use crate::util::error::Result;

use crate::coordinator::optimal_config;
use crate::database::synth::synthesize;
use crate::interference::Schedule;
use crate::models;
use crate::simulator::{simulate, Policy, SimConfig};

use super::{ExpCtx, Output};

const STEP: usize = 20; // queries per paper "time step"

pub fn run(ctx: &ExpCtx) -> Result<()> {
    let mut out = Output::new(ctx, "fig3")?;
    let spec = models::vgg16(ctx.spatial);
    let db = synthesize(&spec, ctx.seed);
    let queries = 25 * STEP;

    // interference events at steps 5/10/15 on different EPs; one removed
    // at step 20 (the paper's storyline)
    let events = [
        (5 * STEP, 1usize, 3usize, 20 * STEP), // stays until end
        (10 * STEP, 2, 9, 15 * STEP),
        (15 * STEP, 3, 6, 5 * STEP), // removed at step 20
    ];
    let schedule = Schedule::from_events(4, queries, &events);
    let r = simulate(
        &db,
        &schedule,
        &SimConfig::new(4, Policy::Odin { alpha: 10 }),
    );

    out.line("# Fig 3 — ODIN reaction timeline (VGG16, 4 EPs; 1 step = 20 queries)");
    out.line("# events: +EP1@5, +EP2@10, +EP3@15, -EP3@20");
    out.line(format!(
        "{:<6} {:>10} {:>12} {:>12}  {}",
        "step", "tput(q/s)", "constrained", "peak", "phase"
    ));
    for step in 0..25 {
        let q0 = step * STEP;
        let q1 = q0 + STEP;
        let window: Vec<f64> = r.inst_throughput[q0..q1].to_vec();
        let mean = window.iter().sum::<f64>() / window.len() as f64;
        let sc = schedule.at(q0 + STEP / 2);
        let (_, b) = optimal_config(&db, sc, 4);
        let constrained = 1.0 / b;
        let serial = (q0..q1).filter(|&q| r.serial[q]).count();
        let phase = if serial > 0 {
            format!("rebalancing ({serial} serial)")
        } else if sc.iter().all(|&s| s == 0) {
            "clean".to_string()
        } else {
            format!("interference {sc:?}")
        };
        out.line(format!(
            "{:<6} {:>10.2} {:>12.2} {:>12.2}  {}",
            step, mean, constrained, r.peak_throughput, phase
        ));
    }
    out.line(format!(
        "# rebalances: {} (expected: one shortly after each event)",
        r.rebalances.len()
    ));
    for e in &r.rebalances {
        out.line(format!(
            "#   at query {:>4} (step {:>2}): {} trials, {:.2} -> {:.2} q/s",
            e.query,
            e.query / STEP,
            e.trials,
            e.throughput_before,
            e.throughput_after
        ));
    }
    out.line("# shape check: throughput tracks the constrained optimum after each");
    out.line("#   reaction and recovers toward peak when interference leaves");
    Ok(())
}
