//! Interference machinery: the paper's Table-1 scenario catalogue, real
//! iBench-style stress generators, query-indexed schedules, and the
//! time-phased dynamic scenario DSL.

pub mod dynamic;
pub mod generator;
pub mod scenarios;
pub mod schedule;

pub use dynamic::{
    DynamicScenario, Phase, TraceEvent, BUILTIN_NAMES, EXTENDED_NAMES,
};
pub use generator::{placement_cores, Stressor};
pub use scenarios::{catalogue, Placement, Scenario, StressKind, NUM_SCENARIOS};
pub use schedule::{EpScenarios, RandomInterference, Schedule};
