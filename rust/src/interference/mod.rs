//! Interference machinery: the paper's Table-1 scenario catalogue, real
//! iBench-style stress generators, and query-indexed schedules.

pub mod generator;
pub mod scenarios;
pub mod schedule;

pub use generator::Stressor;
pub use scenarios::{catalogue, Placement, Scenario, StressKind, NUM_SCENARIOS};
pub use schedule::{EpScenarios, RandomInterference, Schedule};
