//! Trace-driven, time-phased dynamic interference scenarios.
//!
//! The static catalogue (`scenarios`) and the §4.2 random process
//! (`schedule`) exercise ODIN against *memoryless* interference; the
//! paper's actual claim — "detects interference online and automatically
//! re-balances the pipeline stages" — is about interference that *evolves*:
//! co-runners that burst, ramp up, arrive and depart, or migrate between
//! cores. This module is a small scenario DSL for exactly those shapes.
//!
//! A [`DynamicScenario`] is a list of [`Phase`]s (and/or a raw trace of
//! state-change events) over a fixed query horizon; [`compile`] expands it
//! into the same per-query [`Schedule`] the simulator already consumes, so
//! every policy faces the identical, fully deterministic stream. Scenarios
//! come from the builtin catalogue ([`builtin`]) or from JSON files
//! ([`DynamicScenario::load`]); all validation failures are
//! [`OdinError`]s with context — a malformed scenario file must never
//! panic the CLI.
//!
//! [`compile`]: DynamicScenario::compile
//! [`OdinError`]: crate::util::error::OdinError

use crate::json::{parse, Value};
use crate::util::error::{Context, Result};
use crate::{bail, err};

use super::scenarios::NUM_SCENARIOS;
use super::schedule::Schedule;

/// Default execution places of a scenario (the paper's 4-EP pipeline).
pub const DEFAULT_EPS: usize = 4;
/// Default query horizon: long enough for several interference epochs,
/// short enough that the full builtin sweep stays interactive.
pub const DEFAULT_QUERIES: usize = 2000;
/// Sanity bounds on scenario dimensions: validation and compilation
/// materialize per-(query, EP) state, so an absurd horizon in a user
/// scenario file must fail as an [`OdinError`], not abort on allocation.
/// `MAX_SLOTS` bounds the `queries × eps` product (the actual footprint).
///
/// [`OdinError`]: crate::util::error::OdinError
pub const MAX_QUERIES: usize = 1_000_000;
/// Wide enough for a fleet-scale schedule (hundreds of replicas ×
/// [`MAX_REPLICA_EPS`](crate::serving::MAX_REPLICA_EPS) EPs each);
/// `MAX_SLOTS` still bounds the materialized footprint, so a wide
/// scenario must trade query horizon for width.
pub const MAX_EPS: usize = 8192;
pub const MAX_SLOTS: usize = 16_000_000;

/// Builtin scenario names, in catalogue order (stable: golden tests and
/// the `dynamic` experiment iterate this order).
pub const BUILTIN_NAMES: [&str; 5] =
    ["burst", "ramp", "arrivals", "migrate", "storm"];

/// Predictive-control scenario families (ROADMAP item 4), catalogued
/// separately so the `dynamic` experiment's `BUILTIN_NAMES` sweep — and
/// its golden `dynamic.json` bytes — stay untouched. [`builtin`] and
/// [`resolve`] accept both lists.
pub const EXTENDED_NAMES: [&str; 3] = ["diurnal", "flashcrowd", "correlated"];

/// The unit of a scenario's time axis.
///
/// Historically every phase boundary was a **query index** — which makes
/// stressor eras admission-rate dependent: the same scenario hits its
/// burst "later" (in wall-clock terms) under a deeper admission window or
/// a slower arrival rate. `Millis` scenarios fix phase boundaries in
/// **wall-clock milliseconds** instead (virtual milliseconds in the
/// simulator), so one scenario file reproduces identical stressor-era
/// boundaries at any admission depth or arrival rate. `Queries` remains
/// the default — the compatibility shim for every existing scenario file.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScenarioAxis {
    /// Phase fields count query indexes (the historical behavior).
    Queries,
    /// Phase fields count milliseconds since run start; the horizon is
    /// `num_queries` *milliseconds* and the query count comes from the
    /// workload/CLI instead.
    Millis,
}

/// One time-phased interference pattern on the query axis.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Repeating burst: `scenario` lands on `ep` for `duration` queries
    /// every `period` queries, starting at `start`, until the horizon.
    Burst { start: usize, period: usize, duration: usize, ep: usize, scenario: usize },
    /// Ramping co-runner: `ep` steps through the scenario ids in `levels`
    /// (equal sub-spans) across `[start, end)` — e.g. a stressor growing
    /// from 2 to 8 threads.
    Ramp { start: usize, end: usize, ep: usize, levels: Vec<usize> },
    /// Long-lived task: `scenario` occupies `ep` for all of `[start, end)`
    /// (arrives at `start`, departs at `end`).
    Task { start: usize, end: usize, ep: usize, scenario: usize },
    /// Core migration: `scenario` hops to the next EP (round-robin from
    /// EP 0) every `period` queries during `[start, end)`.
    Migrate { start: usize, end: usize, period: usize, scenario: usize },
}

impl Phase {
    fn kind(&self) -> &'static str {
        match self {
            Phase::Burst { .. } => "burst",
            Phase::Ramp { .. } => "ramp",
            Phase::Task { .. } => "task",
            Phase::Migrate { .. } => "migrate",
        }
    }

    /// First query the phase touches.
    fn start(&self) -> usize {
        match *self {
            Phase::Burst { start, .. }
            | Phase::Ramp { start, .. }
            | Phase::Task { start, .. }
            | Phase::Migrate { start, .. } => start,
        }
    }

    /// Expand into (start, ep, scenario, duration) schedule events over a
    /// `horizon`/`num_eps` grid — the single source of truth for both the
    /// slot-exact overlap validation and compilation.
    fn events(
        &self,
        num_eps: usize,
        horizon: usize,
        out: &mut Vec<(usize, usize, usize, usize)>,
    ) {
        match *self {
            Phase::Burst { start, period, duration, ep, scenario } => {
                let mut at = start;
                while at < horizon {
                    out.push((at, ep, scenario, duration));
                    at += period;
                }
            }
            Phase::Ramp { start, end, ep, ref levels } => {
                let end = end.min(horizon);
                let span = end.saturating_sub(start);
                let chunk = (span / levels.len()).max(1);
                for (k, &level) in levels.iter().enumerate() {
                    let s = start + k * chunk;
                    if s >= end {
                        break;
                    }
                    // the last level absorbs the rounding remainder
                    let d = if k + 1 == levels.len() {
                        end - s
                    } else {
                        chunk.min(end - s)
                    };
                    out.push((s, ep, level, d));
                }
            }
            Phase::Task { start, end, ep, scenario } => {
                let end = end.min(horizon);
                if start < end {
                    out.push((start, ep, scenario, end - start));
                }
            }
            Phase::Migrate { start, end, period, scenario } => {
                let end = end.min(horizon);
                let mut at = start;
                let mut hop = 0usize;
                while at < end {
                    let ep = hop % num_eps;
                    out.push((at, ep, scenario, period.min(end - at)));
                    at += period;
                    hop += 1;
                }
            }
        }
    }
}

/// A raw trace record: from query `at` onward, `ep` runs under `scenario`
/// (0 clears it) until the trace changes that EP again.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceEvent {
    pub at: usize,
    pub ep: usize,
    pub scenario: usize,
}

/// A composed dynamic scenario: phases + trace over a fixed horizon.
///
/// `num_queries` is the horizon in `axis` units: query slots for
/// [`ScenarioAxis::Queries`], milliseconds for [`ScenarioAxis::Millis`].
/// The compiled [`Schedule`] indexes the same units — hosts of a `Millis`
/// scenario look its state up by elapsed (wall or virtual) millisecond
/// instead of by query index.
#[derive(Clone, Debug, PartialEq)]
pub struct DynamicScenario {
    pub name: String,
    pub num_eps: usize,
    pub num_queries: usize,
    pub phases: Vec<Phase>,
    pub trace: Vec<TraceEvent>,
    pub axis: ScenarioAxis,
}

impl DynamicScenario {
    /// Build and validate a query-axis scenario (the historical shape);
    /// every constructor funnels through [`with_axis`](Self::with_axis).
    pub fn new(
        name: impl Into<String>,
        num_eps: usize,
        num_queries: usize,
        phases: Vec<Phase>,
        trace: Vec<TraceEvent>,
    ) -> Result<DynamicScenario> {
        Self::with_axis(
            name,
            num_eps,
            num_queries,
            phases,
            trace,
            ScenarioAxis::Queries,
        )
    }

    /// Build and validate with an explicit time axis (`horizon` in axis
    /// units: queries, or milliseconds for a wall-clock scenario).
    pub fn with_axis(
        name: impl Into<String>,
        num_eps: usize,
        horizon: usize,
        phases: Vec<Phase>,
        trace: Vec<TraceEvent>,
        axis: ScenarioAxis,
    ) -> Result<DynamicScenario> {
        let s = DynamicScenario {
            name: name.into(),
            num_eps,
            num_queries: horizon,
            phases,
            trace,
            axis,
        };
        s.validate()?;
        Ok(s)
    }

    fn validate(&self) -> Result<()> {
        let name = &self.name;
        // the name ends up in artifact file names (scenario_<name>.json);
        // keep it a single path-safe token
        if name.is_empty() {
            bail!("scenario name must not be empty");
        }
        if !name
            .chars()
            .all(|c| c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.'))
        {
            bail!(
                "scenario name {name:?} may only contain ASCII \
                 letters, digits, '-', '_' and '.'"
            );
        }
        if self.num_eps == 0 {
            bail!("scenario {name:?}: num_eps must be >= 1");
        }
        if self.num_eps > MAX_EPS {
            bail!(
                "scenario {name:?}: {} EPs exceeds the {MAX_EPS} limit",
                self.num_eps
            );
        }
        if self.num_queries == 0 {
            bail!("scenario {name:?}: num_queries must be >= 1");
        }
        if self.num_queries > MAX_QUERIES {
            bail!(
                "scenario {name:?}: {}-query horizon exceeds the \
                 {MAX_QUERIES} limit",
                self.num_queries
            );
        }
        if self.num_queries.saturating_mul(self.num_eps) > MAX_SLOTS {
            bail!(
                "scenario {name:?}: {} queries x {} EPs exceeds the \
                 {MAX_SLOTS}-slot limit",
                self.num_queries,
                self.num_eps
            );
        }
        if self.phases.is_empty() && self.trace.is_empty() {
            bail!(
                "scenario {name:?}: empty — needs at least one phase or \
                 trace event"
            );
        }
        for (i, p) in self.phases.iter().enumerate() {
            self.validate_phase(i, p)
                .with_context(|| format!("scenario {name:?}"))?;
        }
        // bound the total expansion *work* (slot writes), not just the
        // memory: a burst with period 1 and duration ~horizon respects
        // every dimension cap yet expands to ~horizon² writes. Trace
        // events need no budget — their spans are disjoint per EP, so
        // they are bounded by the slot count already.
        let mut writes = 0usize;
        let mut events = Vec::new();
        for (i, p) in self.phases.iter().enumerate() {
            events.clear();
            p.events(self.num_eps, self.num_queries, &mut events);
            for &(start, _, _, duration) in &events {
                writes = writes.saturating_add(
                    duration.min(self.num_queries.saturating_sub(start)),
                );
            }
            if writes > MAX_SLOTS {
                bail!(
                    "scenario {name:?}: phase {i} pushes the expansion \
                     past the {MAX_SLOTS}-write budget (period too small \
                     for its duration?)"
                );
            }
        }
        self.validate_overlaps()?;
        self.validate_trace()?;
        Ok(())
    }

    fn validate_phase(&self, i: usize, p: &Phase) -> Result<()> {
        let kind = p.kind();
        let check_scenario = |scenario: usize| -> Result<()> {
            if !(1..=NUM_SCENARIOS).contains(&scenario) {
                bail!(
                    "phase {i} ({kind}): scenario id {scenario} out of \
                     range 1..={NUM_SCENARIOS}"
                );
            }
            Ok(())
        };
        let check_ep = |ep: usize| -> Result<()> {
            if ep >= self.num_eps {
                bail!(
                    "phase {i} ({kind}): ep {ep} out of range for \
                     {} EPs",
                    self.num_eps
                );
            }
            Ok(())
        };
        // repetition fields feed `at += period` / `start + duration`
        // arithmetic; cap them so a saturated JSON number (huge floats
        // parse as usize::MAX) can never overflow past the checks
        let check_step = |field: &str, v: usize| -> Result<()> {
            if v > MAX_QUERIES {
                bail!(
                    "phase {i} ({kind}): {field} {v} exceeds the \
                     {MAX_QUERIES} limit"
                );
            }
            Ok(())
        };
        match p {
            Phase::Burst { period, duration, ep, scenario, .. } => {
                check_ep(*ep)?;
                check_scenario(*scenario)?;
                if *period == 0 || *duration == 0 {
                    bail!("phase {i} (burst): period and duration must be >= 1");
                }
                check_step("period", *period)?;
                check_step("duration", *duration)?;
            }
            Phase::Ramp { start, end, ep, levels } => {
                check_ep(*ep)?;
                if levels.is_empty() {
                    bail!("phase {i} (ramp): needs at least one level");
                }
                for &l in levels {
                    check_scenario(l)?;
                }
                if start >= end {
                    bail!(
                        "phase {i} (ramp): out-of-order span [{start}, {end})"
                    );
                }
                // every level must get at least one query, or trailing
                // levels would silently never be scheduled
                let span = (*end).min(self.num_queries).saturating_sub(*start);
                if span < levels.len() {
                    bail!(
                        "phase {i} (ramp): span of {span} queries cannot \
                         fit {} levels",
                        levels.len()
                    );
                }
            }
            Phase::Task { start, end, ep, scenario } => {
                check_ep(*ep)?;
                check_scenario(*scenario)?;
                if start >= end {
                    bail!(
                        "phase {i} (task): out-of-order span [{start}, {end})"
                    );
                }
            }
            Phase::Migrate { start, end, period, scenario } => {
                check_scenario(*scenario)?;
                if *period == 0 {
                    bail!("phase {i} (migrate): period must be >= 1");
                }
                check_step("period", *period)?;
                if start >= end {
                    bail!(
                        "phase {i} (migrate): out-of-order span \
                         [{start}, {end})"
                    );
                }
            }
        }
        // a phase entirely past the horizon would silently compile to
        // nothing — reject it for every kind, not just bursts
        if p.start() >= self.num_queries {
            bail!(
                "phase {i} ({kind}): start {} is past the {}-query horizon",
                p.start(),
                self.num_queries
            );
        }
        Ok(())
    }

    /// Two phases may not claim the same (query, EP) slot — the compiled
    /// schedule would silently depend on phase order otherwise. The check
    /// is slot-exact: interleaved bursts on one EP, or a task scheduled
    /// between a migrating stressor's visits, are legal.
    fn validate_overlaps(&self) -> Result<()> {
        if self.phases.len() < 2 {
            return Ok(()); // nothing to contend with
        }
        const FREE: usize = usize::MAX;
        // flat slot matrix: owner of (query q, EP e) at q * num_eps + e
        let mut owner = vec![FREE; self.num_queries * self.num_eps];
        let mut events = Vec::new();
        for (i, p) in self.phases.iter().enumerate() {
            events.clear();
            p.events(self.num_eps, self.num_queries, &mut events);
            for &(start, ep, _, duration) in &events {
                for q in start..(start + duration).min(self.num_queries) {
                    let slot = &mut owner[q * self.num_eps + ep];
                    // a phase may overlap itself (burst duration > period)
                    if *slot != FREE && *slot != i {
                        bail!(
                            "scenario {:?}: phase {} ({}) and phase \
                             {i} ({}) overlap on EP {ep} at query {q}",
                            self.name,
                            *slot,
                            self.phases[*slot].kind(),
                            p.kind()
                        );
                    }
                    *slot = i;
                }
            }
        }
        Ok(())
    }

    fn validate_trace(&self) -> Result<()> {
        let name = &self.name;
        let mut prev_at = 0usize;
        for (i, ev) in self.trace.iter().enumerate() {
            if i > 0 && ev.at < prev_at {
                bail!(
                    "scenario {name:?}: trace event {i} at query {} is \
                     out of order (previous event at {prev_at})",
                    ev.at
                );
            }
            prev_at = ev.at;
            if ev.at >= self.num_queries {
                bail!(
                    "scenario {name:?}: trace event {i} at query {} is \
                     past the {}-query horizon",
                    ev.at,
                    self.num_queries
                );
            }
            if ev.ep >= self.num_eps {
                bail!(
                    "scenario {name:?}: trace event {i}: ep {} out of \
                     range for {} EPs",
                    ev.ep,
                    self.num_eps
                );
            }
            if ev.scenario > NUM_SCENARIOS {
                bail!(
                    "scenario {name:?}: trace event {i}: scenario id {} \
                     out of range 0..={NUM_SCENARIOS}",
                    ev.scenario
                );
            }
        }
        Ok(())
    }

    /// Expand into the per-query schedule the simulator consumes. Phases
    /// are slot-disjoint by construction; trace events apply last (a
    /// trace can deliberately override phases).
    pub fn compile(&self) -> Schedule {
        let mut events: Vec<(usize, usize, usize, usize)> = Vec::new();
        for p in &self.phases {
            p.events(self.num_eps, self.num_queries, &mut events);
        }
        // trace: each record holds until the next record on the same EP;
        // one reverse pass finds every successor (a forward rescan per
        // record would be quadratic in the trace length)
        const NONE: usize = usize::MAX;
        let mut next_at = vec![NONE; self.num_eps];
        let mut until = vec![self.num_queries; self.trace.len()];
        for (i, ev) in self.trace.iter().enumerate().rev() {
            if next_at[ev.ep] != NONE {
                until[i] = next_at[ev.ep];
            }
            next_at[ev.ep] = ev.at;
        }
        for (i, ev) in self.trace.iter().enumerate() {
            if ev.at < until[i] {
                events.push((ev.at, ev.ep, ev.scenario, until[i] - ev.at));
            }
        }
        Schedule::from_events(self.num_eps, self.num_queries, &events)
    }

    /// Rescale the scenario's query axis to a new `queries` horizon,
    /// preserving each phase's *shape*: every query-axis field (start,
    /// end, period, duration, trace timestamps) scales by
    /// `queries / self.num_queries` with round-half-up; repetition fields
    /// clamp to ≥ 1 and spans to ≥ 1 query so a shrunken phase never
    /// degenerates. The result re-validates, so a horizon too small to
    /// hold a phase (e.g. a ramp with more levels than queries) errors
    /// with context instead of silently compiling to nothing.
    pub fn scaled(&self, queries: usize) -> Result<DynamicScenario> {
        self.adapted(queries, self.num_eps)
    }

    /// [`scaled`](Self::scaled) plus an EP remap (`ep % num_eps`), for
    /// driving a scenario on a pipeline with a different stage count.
    /// Remapping can fold two phases onto one EP; the slot-exact overlap
    /// validation rejects such folds with a clear error.
    ///
    /// Wall-clock ([`ScenarioAxis::Millis`]) scenarios keep their time
    /// axis **absolute**: `queries` only sizes the run, never the phase
    /// boundaries — that invariance is the whole point of the axis.
    pub fn adapted(
        &self,
        queries: usize,
        num_eps: usize,
    ) -> Result<DynamicScenario> {
        // degenerate targets are rejected *before* the identity
        // early-return: on the ms axis the horizon never tracks
        // `queries`, so `adapted(0, self.num_eps)` used to slip through
        // the identity check and hand a zero-query run to the host
        if queries == 0 || num_eps == 0 {
            bail!(
                "cannot adapt scenario {:?} to {queries} queries / \
                 {num_eps} EPs",
                self.name
            );
        }
        let rescale_time = self.axis == ScenarioAxis::Queries;
        let horizon = if rescale_time { queries } else { self.num_queries };
        if horizon == self.num_queries && num_eps == self.num_eps {
            return Ok(self.clone());
        }
        // round-half-up rational scaling; u128 guards against overflow at
        // the MAX_QUERIES end of the range. A Millis axis scales by 1/1
        // (identity): wall-clock boundaries do not move with --queries.
        let (old, new) = if rescale_time {
            (self.num_queries as u128, queries as u128)
        } else {
            (1, 1)
        };
        let s = |v: usize| -> usize { ((v as u128 * new + old / 2) / old) as usize };
        let sp = |v: usize| s(v).max(1); // periods/durations stay >= 1
        let span = |a: usize, b: usize| (s(a), s(b).max(s(a) + 1));
        let re = |e: usize| e % num_eps;
        let phases = self
            .phases
            .iter()
            .map(|p| match *p {
                Phase::Burst { start, period, duration, ep, scenario } => {
                    Phase::Burst {
                        start: s(start),
                        period: sp(period),
                        duration: sp(duration),
                        ep: re(ep),
                        scenario,
                    }
                }
                Phase::Ramp { start, end, ep, ref levels } => {
                    let (start, end) = span(start, end);
                    Phase::Ramp { start, end, ep: re(ep), levels: levels.clone() }
                }
                Phase::Task { start, end, ep, scenario } => {
                    let (start, end) = span(start, end);
                    Phase::Task { start, end, ep: re(ep), scenario }
                }
                Phase::Migrate { start, end, period, scenario } => {
                    let (start, end) = span(start, end);
                    Phase::Migrate { start, end, period: sp(period), scenario }
                }
            })
            .collect();
        let trace = self
            .trace
            .iter()
            .map(|ev| TraceEvent { at: s(ev.at), ep: re(ev.ep), scenario: ev.scenario })
            .collect();
        DynamicScenario::with_axis(
            self.name.clone(),
            num_eps,
            horizon,
            phases,
            trace,
            self.axis,
        )
        .with_context(|| {
            format!(
                "adapting scenario {:?} ({} queries, {} EPs) to \
                 {queries} queries, {num_eps} EPs",
                self.name, self.num_queries, self.num_eps
            )
        })
    }

    // -- JSON -----------------------------------------------------------

    /// Parse a scenario document (this example is slot-disjoint: the
    /// migration's four hops land on EPs 0..3 during 700..900, clear of
    /// the burst windows on EP 0):
    ///
    /// ```json
    /// {
    ///  "name": "my-scenario", "eps": 4, "queries": 1000,
    ///  "phases": [
    ///   {"kind": "burst", "start": 0, "period": 200, "duration": 50,
    ///    "ep": 0, "scenario": 3},
    ///   {"kind": "ramp", "start": 100, "end": 600, "ep": 1,
    ///    "levels": [7, 8, 9]},
    ///   {"kind": "task", "start": 200, "end": 700, "ep": 2, "scenario": 6},
    ///   {"kind": "migrate", "start": 700, "end": 900, "period": 50,
    ///    "scenario": 8}
    ///  ],
    ///  "trace": [{"at": 0, "ep": 3, "scenario": 5},
    ///            {"at": 500, "ep": 3, "scenario": 0}]
    /// }
    /// ```
    pub fn from_json(v: &Value) -> Result<DynamicScenario> {
        if v.as_obj().is_none() {
            bail!("scenario document must be a JSON object");
        }
        check_keys(
            v,
            &["eps", "horizon_ms", "name", "phases", "queries", "trace", "unit"],
            "scenario",
        )?;
        // missing name defaults; a present-but-non-string name is an
        // error, not a silent "custom"
        let name = match v.get("name") {
            Value::Null => "custom".to_string(),
            other => other
                .as_str()
                .ok_or_else(|| err!("field \"name\" must be a string"))?
                .to_string(),
        };
        let num_eps = opt_usize(v, "eps", DEFAULT_EPS)?;
        // the time axis: "queries" (default, the compatibility shim for
        // every pre-existing scenario file) or "ms" (wall-clock phase
        // boundaries; the horizon comes from "horizon_ms" and the query
        // count from the workload/CLI). "horizon_ms" alone implies ms.
        let unit = match v.get("unit") {
            Value::Null => None,
            other => match other.as_str() {
                Some("queries") => Some(ScenarioAxis::Queries),
                Some("ms") => Some(ScenarioAxis::Millis),
                _ => bail!("field \"unit\" must be \"queries\" or \"ms\""),
            },
        };
        let has_ms = !v.get("horizon_ms").is_null();
        if has_ms && !v.get("queries").is_null() {
            bail!(
                "scenario {name:?}: give either \"queries\" (query-axis) \
                 or \"horizon_ms\" (wall-clock axis), not both"
            );
        }
        if unit == Some(ScenarioAxis::Millis) && !has_ms {
            bail!("scenario {name:?}: \"unit\": \"ms\" requires \"horizon_ms\"");
        }
        if unit == Some(ScenarioAxis::Queries) && has_ms {
            bail!(
                "scenario {name:?}: \"horizon_ms\" contradicts \
                 \"unit\": \"queries\""
            );
        }
        let (axis, num_queries) = if has_ms {
            (
                ScenarioAxis::Millis,
                req_usize(v, "horizon_ms", "scenario")?,
            )
        } else {
            (
                ScenarioAxis::Queries,
                opt_usize(v, "queries", DEFAULT_QUERIES)?,
            )
        };
        let mut phases = Vec::new();
        if !v.get("phases").is_null() {
            let arr = v
                .get("phases")
                .as_arr()
                .ok_or_else(|| err!("\"phases\" must be an array"))?;
            for (i, pv) in arr.iter().enumerate() {
                phases.push(parse_phase(pv, i)?);
            }
        }
        let mut trace = Vec::new();
        if !v.get("trace").is_null() {
            let arr = v
                .get("trace")
                .as_arr()
                .ok_or_else(|| err!("\"trace\" must be an array"))?;
            for (i, tv) in arr.iter().enumerate() {
                let what = format!("trace event {i}");
                check_keys(tv, &["at", "ep", "scenario"], &what)?;
                trace.push(TraceEvent {
                    at: req_usize(tv, "at", &what)?,
                    ep: req_usize(tv, "ep", &what)?,
                    scenario: req_usize(tv, "scenario", &what)?,
                });
            }
        }
        DynamicScenario::with_axis(name, num_eps, num_queries, phases, trace, axis)
    }

    /// Parse a scenario from JSON text.
    pub fn from_json_str(text: &str) -> Result<DynamicScenario> {
        let v = parse(text).context("parsing scenario json")?;
        DynamicScenario::from_json(&v)
    }

    /// Load a scenario file.
    pub fn load(path: &str) -> Result<DynamicScenario> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading scenario file {path:?}"))?;
        DynamicScenario::from_json_str(&text)
            .with_context(|| format!("loading scenario file {path:?}"))
    }
}

fn req_usize(v: &Value, key: &str, what: &str) -> Result<usize> {
    v.get(key)
        .as_usize()
        .ok_or_else(|| err!("{what}: missing or non-integer field {key:?}"))
}

/// Reject unrecognized keys: a typo'd field must error, not silently
/// fall back to a default. `allowed` is sorted for the message.
fn check_keys(v: &Value, allowed: &[&str], what: &str) -> Result<()> {
    if let Some(obj) = v.as_obj() {
        for k in obj.keys() {
            if !allowed.contains(&k.as_str()) {
                bail!(
                    "{what}: unknown field {k:?} (allowed: {})",
                    allowed.join(", ")
                );
            }
        }
    }
    Ok(())
}

fn opt_usize(v: &Value, key: &str, default: usize) -> Result<usize> {
    if v.get(key).is_null() {
        return Ok(default);
    }
    v.get(key)
        .as_usize()
        .ok_or_else(|| err!("field {key:?} must be a non-negative integer"))
}

fn parse_phase(v: &Value, i: usize) -> Result<Phase> {
    let what = format!("phase {i}");
    let kind = v
        .get("kind")
        .as_str()
        .ok_or_else(|| err!("{what}: missing \"kind\""))?;
    match kind {
        "burst" => check_keys(
            v,
            &["duration", "ep", "kind", "period", "scenario", "start"],
            &what,
        )?,
        "ramp" => check_keys(v, &["end", "ep", "kind", "levels", "start"], &what)?,
        "task" => check_keys(v, &["end", "ep", "kind", "scenario", "start"], &what)?,
        "migrate" => {
            check_keys(v, &["end", "kind", "period", "scenario", "start"], &what)?
        }
        _ => {}
    }
    Ok(match kind {
        "burst" => Phase::Burst {
            start: req_usize(v, "start", &what)?,
            period: req_usize(v, "period", &what)?,
            duration: req_usize(v, "duration", &what)?,
            ep: req_usize(v, "ep", &what)?,
            scenario: req_usize(v, "scenario", &what)?,
        },
        "ramp" => Phase::Ramp {
            start: req_usize(v, "start", &what)?,
            end: req_usize(v, "end", &what)?,
            ep: req_usize(v, "ep", &what)?,
            levels: v
                .get("levels")
                .as_usize_vec()
                .ok_or_else(|| err!("{what}: \"levels\" must be an integer array"))?,
        },
        "task" => Phase::Task {
            start: req_usize(v, "start", &what)?,
            end: req_usize(v, "end", &what)?,
            ep: req_usize(v, "ep", &what)?,
            scenario: req_usize(v, "scenario", &what)?,
        },
        "migrate" => Phase::Migrate {
            start: req_usize(v, "start", &what)?,
            end: req_usize(v, "end", &what)?,
            period: req_usize(v, "period", &what)?,
            scenario: req_usize(v, "scenario", &what)?,
        },
        other => bail!("{what}: unknown kind {other:?} (burst|ramp|task|migrate)"),
    })
}

/// The builtin catalogue. Scenario ids reference Table 1: 3 = cpu_8t_same,
/// 5 = cpu_4t_socket, 6 = cpu_8t_socket, 7..9 = membw_{2,4,8}t_same,
/// 10..12 = membw_{2,4,8}t_socket.
pub fn builtin(name: &str) -> Result<DynamicScenario> {
    let (eps, q) = (DEFAULT_EPS, DEFAULT_QUERIES);
    match name {
        // repeating long bursts on two EPs, offset so the pipeline never
        // settles for more than a few hundred queries
        "burst" => DynamicScenario::new(
            "burst",
            eps,
            q,
            vec![
                Phase::Burst { start: 100, period: 400, duration: 150, ep: 1, scenario: 9 },
                Phase::Burst { start: 300, period: 400, duration: 100, ep: 3, scenario: 3 },
            ],
            Vec::new(),
        ),
        // a co-runner on EP 2 growing from 2 to 8 membw threads
        "ramp" => DynamicScenario::new(
            "ramp",
            eps,
            q,
            vec![Phase::Ramp { start: 200, end: 1800, ep: 2, levels: vec![7, 8, 9] }],
            Vec::new(),
        ),
        // three long-lived tasks arriving and departing at staggered times
        "arrivals" => DynamicScenario::new(
            "arrivals",
            eps,
            q,
            vec![
                Phase::Task { start: 150, end: 1100, ep: 0, scenario: 6 },
                Phase::Task { start: 500, end: 1500, ep: 2, scenario: 12 },
                Phase::Task { start: 900, end: 1900, ep: 3, scenario: 5 },
            ],
            Vec::new(),
        ),
        // one stressor hopping round-robin across all EPs
        "migrate" => DynamicScenario::new(
            "migrate",
            eps,
            q,
            vec![Phase::Migrate { start: 100, end: 1900, period: 300, scenario: 8 }],
            Vec::new(),
        ),
        // everything at once, on disjoint EPs
        "storm" => DynamicScenario::new(
            "storm",
            eps,
            q,
            vec![
                Phase::Burst { start: 0, period: 500, duration: 200, ep: 0, scenario: 3 },
                Phase::Ramp { start: 400, end: 1600, ep: 2, levels: vec![10, 11, 12] },
                Phase::Task { start: 800, end: 1800, ep: 3, scenario: 7 },
            ],
            Vec::new(),
        ),
        // -- predictive-control families (EXTENDED_NAMES) ---------------
        // diurnal: a sine-like swell sampled into ramp steps — EP 1
        // climbs while EP 2 recedes, then they swap for the second
        // half-cycle, so the aggregate load oscillates smoothly and the
        // *trend* (the slope a forecaster can see) is never zero for long
        "diurnal" => DynamicScenario::new(
            "diurnal",
            eps,
            q,
            vec![
                Phase::Ramp { start: 0, end: 1000, ep: 1, levels: vec![7, 8, 9] },
                Phase::Ramp { start: 1000, end: 2000, ep: 1, levels: vec![9, 8, 7] },
                Phase::Ramp { start: 0, end: 1000, ep: 2, levels: vec![12, 11, 10] },
                Phase::Ramp { start: 1000, end: 2000, ep: 2, levels: vec![10, 11, 12] },
            ],
            Vec::new(),
        ),
        // flashcrowd: a long quiet prelude, then a sudden two-EP spike
        // landing mid-observation-window (starts offset from the
        // 100-query window grid) — the scenario a reactive controller is
        // guaranteed to eat a part-window of violations on
        "flashcrowd" => DynamicScenario::new(
            "flashcrowd",
            eps,
            q,
            vec![
                Phase::Burst { start: 250, period: 600, duration: 120, ep: 1, scenario: 3 },
                Phase::Task { start: 710, end: 1350, ep: 0, scenario: 9 },
                Phase::Task { start: 730, end: 1330, ep: 2, scenario: 12 },
            ],
            Vec::new(),
        ),
        // correlated: synchronized bursts on three EPs at once (tenant
        // demand spiking in lock-step), same windows, different stressor
        // intensities — no single-EP fix helps, the whole pipeline must
        // rebalance at every era edge
        "correlated" => DynamicScenario::new(
            "correlated",
            eps,
            q,
            vec![
                Phase::Burst { start: 150, period: 500, duration: 180, ep: 0, scenario: 6 },
                Phase::Burst { start: 150, period: 500, duration: 180, ep: 1, scenario: 9 },
                Phase::Burst { start: 150, period: 500, duration: 180, ep: 3, scenario: 12 },
            ],
            Vec::new(),
        ),
        other => bail!(
            "unknown scenario {other:?} (builtins: {}; extended: {})",
            BUILTIN_NAMES.join(", "),
            EXTENDED_NAMES.join(", ")
        ),
    }
}

/// Resolve a CLI argument: a builtin name, or a path to a scenario file.
/// A spec matching both (a file literally named like a builtin) is
/// ambiguous and rejected — prefix the file with `./` to load it.
pub fn resolve(spec: &str) -> Result<DynamicScenario> {
    let is_builtin =
        BUILTIN_NAMES.contains(&spec) || EXTENDED_NAMES.contains(&spec);
    let is_file = std::path::Path::new(spec).is_file();
    match (is_builtin, is_file) {
        (true, true) => Err(err!(
            "scenario {spec:?} is both a builtin name and an existing \
             file; use ./{spec} to load the file"
        )),
        (true, false) => builtin(spec),
        (false, true) => DynamicScenario::load(spec),
        (false, false) => Err(err!(
            "unknown scenario {spec:?}: not a builtin ({}, {}) and not a file",
            BUILTIN_NAMES.join(", "),
            EXTENDED_NAMES.join(", ")
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::error::OdinError;

    fn chain(e: &OdinError) -> String {
        format!("{e:#}")
    }

    #[test]
    fn builtins_all_compile() {
        for name in BUILTIN_NAMES {
            let s = builtin(name).unwrap();
            assert_eq!(s.name, name);
            let sched = s.compile();
            assert_eq!(sched.num_queries(), s.num_queries);
            assert_eq!(sched.num_eps, s.num_eps);
            assert!(
                sched.interference_load() > 0.0,
                "{name} induces no interference"
            );
            assert!(
                !sched.change_points.is_empty(),
                "{name} never changes state"
            );
        }
    }

    #[test]
    fn builtin_names_are_distinct_scenarios() {
        // the acceptance bar: at least 4 distinct dynamic scenarios
        assert!(BUILTIN_NAMES.len() >= 4);
        let loads: Vec<f64> = BUILTIN_NAMES
            .iter()
            .map(|n| builtin(n).unwrap().compile().interference_load())
            .collect();
        for i in 0..loads.len() {
            for j in (i + 1)..loads.len() {
                assert_ne!(loads[i], loads[j], "{i} vs {j}");
            }
        }
    }

    #[test]
    fn unknown_builtin_is_error_with_names() {
        let e = builtin("nope").unwrap_err();
        assert!(chain(&e).contains("burst"), "{e:#}");
        assert!(chain(&e).contains("flashcrowd"), "{e:#}");
        let e = resolve("also-nope").unwrap_err();
        assert!(chain(&e).contains("not a builtin"), "{e:#}");
    }

    #[test]
    fn extended_builtins_compile_scale_and_resolve() {
        // the predictive-control families live outside BUILTIN_NAMES (the
        // dynamic experiment's golden sweep order must not grow) but get
        // the same guarantees: they compile, induce interference, change
        // state, scale to any reasonable horizon, and resolve by name
        for name in EXTENDED_NAMES {
            let s = builtin(name).unwrap();
            assert_eq!(s.name, name);
            let sched = s.compile();
            assert!(
                sched.interference_load() > 0.0,
                "{name} induces no interference"
            );
            assert!(!sched.change_points.is_empty(), "{name}");
            for q in [50, 123, 2000, 10_000] {
                let sc = s
                    .scaled(q)
                    .unwrap_or_else(|e| panic!("{name} scaled to {q}: {e:#}"));
                assert!(
                    sc.compile().interference_load() > 0.0,
                    "{name}@{q} lost all interference"
                );
            }
            assert_eq!(resolve(name).unwrap().name, name);
        }
        assert!(!BUILTIN_NAMES.iter().any(|n| EXTENDED_NAMES.contains(n)));
    }

    #[test]
    fn burst_compiles_to_expected_windows() {
        let s = DynamicScenario::new(
            "b",
            2,
            100,
            vec![Phase::Burst { start: 10, period: 40, duration: 5, ep: 1, scenario: 2 }],
            Vec::new(),
        )
        .unwrap();
        let sched = s.compile();
        for q in 0..100 {
            let want = matches!(q, 10..=14 | 50..=54 | 90..=94);
            assert_eq!(sched.at(q)[1] == 2, want, "q={q}");
            assert_eq!(sched.at(q)[0], 0);
        }
    }

    #[test]
    fn ramp_steps_through_levels() {
        let s = DynamicScenario::new(
            "r",
            2,
            100,
            vec![Phase::Ramp { start: 10, end: 70, ep: 0, levels: vec![1, 2, 3] }],
            Vec::new(),
        )
        .unwrap();
        let sched = s.compile();
        assert_eq!(sched.at(9)[0], 0);
        assert_eq!(sched.at(10)[0], 1);
        assert_eq!(sched.at(30)[0], 2);
        assert_eq!(sched.at(50)[0], 3);
        assert_eq!(sched.at(69)[0], 3);
        assert_eq!(sched.at(70)[0], 0);
    }

    #[test]
    fn migrate_hops_round_robin() {
        let s = DynamicScenario::new(
            "m",
            3,
            90,
            vec![Phase::Migrate { start: 0, end: 90, period: 30, scenario: 4 }],
            Vec::new(),
        )
        .unwrap();
        let sched = s.compile();
        assert_eq!(sched.at(0), &vec![4, 0, 0]);
        assert_eq!(sched.at(30), &vec![0, 4, 0]);
        assert_eq!(sched.at(60), &vec![0, 0, 4]);
    }

    #[test]
    fn trace_holds_until_next_event_on_same_ep() {
        let s = DynamicScenario::new(
            "t",
            2,
            50,
            Vec::new(),
            vec![
                TraceEvent { at: 5, ep: 0, scenario: 7 },
                TraceEvent { at: 10, ep: 1, scenario: 2 },
                TraceEvent { at: 20, ep: 0, scenario: 0 },
            ],
        )
        .unwrap();
        let sched = s.compile();
        assert_eq!(sched.at(4), &vec![0, 0]);
        assert_eq!(sched.at(5), &vec![7, 0]);
        assert_eq!(sched.at(12), &vec![7, 2]);
        assert_eq!(sched.at(20), &vec![0, 2]);
        assert_eq!(sched.at(49), &vec![0, 2]);
    }

    // -- parsing / validation edge cases (satellite) --------------------

    #[test]
    fn empty_scenario_is_error_not_panic() {
        let e = DynamicScenario::from_json_str(r#"{"name": "x"}"#).unwrap_err();
        assert!(chain(&e).contains("empty"), "{e:#}");
        let e = DynamicScenario::from_json_str(
            r#"{"name": "x", "trace": [], "phases": []}"#,
        )
        .unwrap_err();
        assert!(chain(&e).contains("empty"), "{e:#}");
    }

    #[test]
    fn overlapping_phases_rejected() {
        let e = DynamicScenario::new(
            "o",
            4,
            1000,
            vec![
                Phase::Task { start: 100, end: 500, ep: 1, scenario: 2 },
                Phase::Task { start: 400, end: 800, ep: 1, scenario: 3 },
            ],
            Vec::new(),
        )
        .unwrap_err();
        assert!(chain(&e).contains("overlap"), "{e:#}");
        // disjoint spans on the same EP are fine
        DynamicScenario::new(
            "o2",
            4,
            1000,
            vec![
                Phase::Task { start: 100, end: 400, ep: 1, scenario: 2 },
                Phase::Task { start: 400, end: 800, ep: 1, scenario: 3 },
            ],
            Vec::new(),
        )
        .unwrap();
        // same span on different EPs is fine
        DynamicScenario::new(
            "o3",
            4,
            1000,
            vec![
                Phase::Task { start: 100, end: 500, ep: 1, scenario: 2 },
                Phase::Task { start: 100, end: 500, ep: 2, scenario: 3 },
            ],
            Vec::new(),
        )
        .unwrap();
    }

    #[test]
    fn overlap_check_is_slot_exact() {
        // migrate(0..600, period 100) visits EP 3 only during 300..400;
        // a task on EP 3 that touches that visit clashes...
        let e = DynamicScenario::new(
            "m",
            4,
            1000,
            vec![
                Phase::Migrate { start: 0, end: 600, period: 100, scenario: 4 },
                Phase::Task { start: 350, end: 900, ep: 3, scenario: 2 },
            ],
            Vec::new(),
        )
        .unwrap_err();
        assert!(chain(&e).contains("overlap"), "{e:#}");
        // ...while one scheduled between visits is legal
        DynamicScenario::new(
            "m2",
            4,
            1000,
            vec![
                Phase::Migrate { start: 0, end: 600, period: 100, scenario: 4 },
                Phase::Task { start: 450, end: 900, ep: 3, scenario: 2 },
            ],
            Vec::new(),
        )
        .unwrap();
        // interleaved bursts on ONE EP are legal when temporally disjoint
        DynamicScenario::new(
            "m3",
            2,
            1000,
            vec![
                Phase::Burst { start: 0, period: 400, duration: 100, ep: 1, scenario: 2 },
                Phase::Burst { start: 200, period: 400, duration: 100, ep: 1, scenario: 9 },
            ],
            Vec::new(),
        )
        .unwrap();
        // ...and clash when their windows collide
        let e = DynamicScenario::new(
            "m4",
            2,
            1000,
            vec![
                Phase::Burst { start: 0, period: 400, duration: 300, ep: 1, scenario: 2 },
                Phase::Burst { start: 200, period: 400, duration: 100, ep: 1, scenario: 9 },
            ],
            Vec::new(),
        )
        .unwrap_err();
        assert!(chain(&e).contains("overlap"), "{e:#}");
    }

    #[test]
    fn phases_past_the_horizon_rejected_for_every_kind() {
        let mk = |p: Phase| DynamicScenario::new("late", 4, 100, vec![p], Vec::new());
        for p in [
            Phase::Burst { start: 100, period: 10, duration: 5, ep: 0, scenario: 1 },
            Phase::Ramp { start: 150, end: 200, ep: 0, levels: vec![1] },
            Phase::Task { start: 100, end: 200, ep: 0, scenario: 1 },
            Phase::Migrate { start: 500, end: 600, period: 10, scenario: 1 },
        ] {
            let e = mk(p).unwrap_err();
            assert!(chain(&e).contains("past the"), "{e:#}");
        }
    }

    #[test]
    fn out_of_order_timestamps_rejected() {
        let e = DynamicScenario::new(
            "t",
            2,
            100,
            Vec::new(),
            vec![
                TraceEvent { at: 50, ep: 0, scenario: 1 },
                TraceEvent { at: 10, ep: 1, scenario: 2 },
            ],
        )
        .unwrap_err();
        assert!(chain(&e).contains("out of order"), "{e:#}");
        // reversed phase spans are also out-of-order
        let e = DynamicScenario::new(
            "t2",
            2,
            100,
            vec![Phase::Task { start: 80, end: 20, ep: 0, scenario: 1 }],
            Vec::new(),
        )
        .unwrap_err();
        assert!(chain(&e).contains("out-of-order"), "{e:#}");
    }

    #[test]
    fn bad_ids_and_ranges_rejected() {
        // scenario id 0 / 13 invalid in phases
        for bad in [0usize, NUM_SCENARIOS + 1] {
            let e = DynamicScenario::new(
                "s",
                2,
                100,
                vec![Phase::Task { start: 0, end: 50, ep: 0, scenario: bad }],
                Vec::new(),
            )
            .unwrap_err();
            assert!(chain(&e).contains("out of range"), "{e:#}");
        }
        // ep out of range
        let e = DynamicScenario::new(
            "s",
            2,
            100,
            vec![Phase::Task { start: 0, end: 50, ep: 5, scenario: 1 }],
            Vec::new(),
        )
        .unwrap_err();
        assert!(chain(&e).contains("ep 5"), "{e:#}");
        // zero-size horizon
        let e = DynamicScenario::new(
            "s",
            2,
            0,
            vec![Phase::Task { start: 0, end: 50, ep: 0, scenario: 1 }],
            Vec::new(),
        )
        .unwrap_err();
        assert!(chain(&e).contains("num_queries"), "{e:#}");
    }

    #[test]
    fn json_roundtrip_of_all_phase_kinds() {
        // migrate(700..900, period 50) hops ep0@700, ep1@750, ep2@800,
        // ep3@850 — slot-exactly disjoint from the burst's ep0 windows
        // (…, 600..650, 800..850), the ramp (ep1, 100..600) and the task
        // (ep2, 200..700), so the full four-kind document is legal
        let text = r#"{
          "name": "full", "eps": 4, "queries": 1000,
          "phases": [
            {"kind": "burst", "start": 0, "period": 200, "duration": 50,
             "ep": 0, "scenario": 3},
            {"kind": "ramp", "start": 100, "end": 600, "ep": 1,
             "levels": [1, 2, 3]},
            {"kind": "task", "start": 200, "end": 700, "ep": 2, "scenario": 6},
            {"kind": "migrate", "start": 700, "end": 900, "period": 50,
             "scenario": 8}
          ]
        }"#;
        let s = DynamicScenario::from_json_str(text).unwrap();
        assert_eq!(s.phases.len(), 4);
        let sched = s.compile();
        assert_eq!(sched.at(0)[0], 3);
        assert_eq!(sched.at(150)[1], 1);
        assert_eq!(sched.at(250)[2], 6);
        assert_eq!(sched.at(720)[0], 8);
        assert_eq!(sched.at(860)[3], 8);

        // shift the migration to start at 600: its first hop lands on
        // ep0 during the burst's 600..650 window — rejected
        let clashing = text.replace("\"start\": 700", "\"start\": 600");
        let e = DynamicScenario::from_json_str(&clashing).unwrap_err();
        assert!(chain(&e).contains("overlap"), "{e:#}");
    }

    #[test]
    fn absurd_dimensions_error_instead_of_allocating() {
        // a hostile "queries"/"eps" must come back as an OdinError long
        // before any per-slot state is materialized
        let e = DynamicScenario::from_json_str(
            r#"{"queries": 100000000000,
                "phases": [{"kind": "task", "start": 0, "end": 10,
                            "ep": 0, "scenario": 1}]}"#,
        )
        .unwrap_err();
        assert!(chain(&e).contains("limit"), "{e:#}");
        let e = DynamicScenario::from_json_str(
            r#"{"eps": 100000,
                "trace": [{"at": 0, "ep": 0, "scenario": 1}]}"#,
        )
        .unwrap_err();
        assert!(chain(&e).contains("limit"), "{e:#}");
        // dimensions fine individually but absurd combined
        let e = DynamicScenario::new(
            "wide",
            MAX_EPS,
            MAX_QUERIES,
            vec![Phase::Task { start: 0, end: 10, ep: 0, scenario: 1 }],
            Vec::new(),
        )
        .unwrap_err();
        assert!(chain(&e).contains("slot limit"), "{e:#}");
    }

    #[test]
    fn path_hostile_names_rejected() {
        // the name flows into scenario_<name>.json artifact paths
        // ("." is allowed: names always land behind a "scenario_" prefix,
        // so dots cannot form a traversal)
        for bad in ["", "a/b", "a b", "x\\y"] {
            let e = DynamicScenario::new(
                bad,
                2,
                100,
                vec![Phase::Task { start: 0, end: 50, ep: 0, scenario: 1 }],
                Vec::new(),
            )
            .unwrap_err();
            assert!(chain(&e).contains("name"), "{bad:?}: {e:#}");
        }
    }

    #[test]
    fn saturated_repetition_fields_error_instead_of_overflowing() {
        // a huge JSON float saturates to usize::MAX through as_usize;
        // the caps must reject it before any `at += period` arithmetic
        let e = DynamicScenario::from_json_str(
            r#"{"queries": 100,
                "phases": [{"kind": "burst", "start": 1,
                            "period": 100000000000000000000,
                            "duration": 5, "ep": 0, "scenario": 1}]}"#,
        )
        .unwrap_err();
        assert!(chain(&e).contains("period"), "{e:#}");
        let e = DynamicScenario::from_json_str(
            r#"{"queries": 100,
                "phases": [{"kind": "burst", "start": 1, "period": 10,
                            "duration": 100000000000000000000,
                            "ep": 0, "scenario": 1}]}"#,
        )
        .unwrap_err();
        assert!(chain(&e).contains("duration"), "{e:#}");
        let e = DynamicScenario::from_json_str(
            r#"{"queries": 100,
                "phases": [{"kind": "migrate", "start": 0, "end": 90,
                            "period": 100000000000000000000,
                            "scenario": 1}]}"#,
        )
        .unwrap_err();
        assert!(chain(&e).contains("period"), "{e:#}");
    }

    #[test]
    fn ramp_span_must_fit_its_levels() {
        let e = DynamicScenario::new(
            "r",
            2,
            100,
            vec![Phase::Ramp { start: 0, end: 2, ep: 0, levels: vec![1, 2, 3] }],
            Vec::new(),
        )
        .unwrap_err();
        assert!(chain(&e).contains("cannot fit"), "{e:#}");
        // a span of exactly levels.len() is the minimum legal ramp
        let s = DynamicScenario::new(
            "r2",
            2,
            100,
            vec![Phase::Ramp { start: 0, end: 3, ep: 0, levels: vec![1, 2, 3] }],
            Vec::new(),
        )
        .unwrap();
        let sched = s.compile();
        assert_eq!(sched.at(0)[0], 1);
        assert_eq!(sched.at(1)[0], 2);
        assert_eq!(sched.at(2)[0], 3);
        assert_eq!(sched.at(3)[0], 0);
    }

    #[test]
    fn documented_example_scenario_is_valid() {
        // the exact document shown in README.md / the from_json doc
        // comment must load and compile
        let s = DynamicScenario::from_json_str(
            r#"{
             "name": "my-scenario", "eps": 4, "queries": 1000,
             "phases": [
              {"kind": "burst",   "start": 0, "period": 200, "duration": 50,
               "ep": 0, "scenario": 3},
              {"kind": "ramp",    "start": 100, "end": 600, "ep": 1,
               "levels": [7, 8, 9]},
              {"kind": "task",    "start": 200, "end": 700, "ep": 2, "scenario": 6},
              {"kind": "migrate", "start": 700, "end": 900, "period": 50,
               "scenario": 8}
             ],
             "trace": [{"at": 0, "ep": 3, "scenario": 5},
                       {"at": 500, "ep": 3, "scenario": 0}]
            }"#,
        )
        .unwrap();
        let sched = s.compile();
        assert_eq!(sched.at(0)[3], 5); // trace task on EP 3
        assert_eq!(sched.at(500)[3], 0); // trace clears it (overriding
                                         // the migration's EP-3 hop too)
        assert_eq!(sched.at(860)[3], 0);
        assert_eq!(sched.at(720)[0], 8); // migration hop 0
    }

    #[test]
    fn json_defaults_and_bad_fields() {
        let s = DynamicScenario::from_json_str(
            r#"{"trace": [{"at": 0, "ep": 0, "scenario": 1}]}"#,
        )
        .unwrap();
        assert_eq!(s.name, "custom");
        assert_eq!(s.num_eps, DEFAULT_EPS);
        assert_eq!(s.num_queries, DEFAULT_QUERIES);

        // unknown phase kind
        let e = DynamicScenario::from_json_str(
            r#"{"phases": [{"kind": "quake", "start": 0}]}"#,
        )
        .unwrap_err();
        assert!(chain(&e).contains("unknown kind"), "{e:#}");
        // missing field
        let e = DynamicScenario::from_json_str(
            r#"{"phases": [{"kind": "task", "start": 0, "end": 10, "ep": 0}]}"#,
        )
        .unwrap_err();
        assert!(chain(&e).contains("scenario"), "{e:#}");
        // malformed json surfaces the parser's location, not a panic
        let e = DynamicScenario::from_json_str("{").unwrap_err();
        assert!(chain(&e).contains("parsing scenario json"), "{e:#}");
        // a non-object document is rejected up front
        let e = DynamicScenario::from_json_str("[1, 2]").unwrap_err();
        assert!(chain(&e).contains("JSON object"), "{e:#}");
    }

    #[test]
    fn unknown_keys_rejected_not_ignored() {
        // a typo'd field must error, not silently fall back to a default
        let e = DynamicScenario::from_json_str(
            r#"{"querys": 500,
                "phases": [{"kind": "task", "start": 0, "end": 400,
                            "ep": 0, "scenario": 3}]}"#,
        )
        .unwrap_err();
        assert!(chain(&e).contains("querys"), "{e:#}");
        let e = DynamicScenario::from_json_str(
            r#"{"phases": [{"kind": "burst", "start": 0, "period": 10,
                            "durration": 5, "ep": 0, "scenario": 1}]}"#,
        )
        .unwrap_err();
        assert!(chain(&e).contains("durration"), "{e:#}");
        let e = DynamicScenario::from_json_str(
            r#"{"trace": [{"at": 0, "ep": 0, "scenario": 1, "sc": 2}]}"#,
        )
        .unwrap_err();
        assert!(chain(&e).contains("unknown field \"sc\""), "{e:#}");
        // a wrong-typed name must error, not coerce to "custom"
        let e = DynamicScenario::from_json_str(
            r#"{"name": 42, "trace": [{"at": 0, "ep": 0, "scenario": 1}]}"#,
        )
        .unwrap_err();
        assert!(chain(&e).contains("name"), "{e:#}");
    }

    #[test]
    fn expansion_work_budget_enforced() {
        // dimension-cap-compliant but quadratic: period 1, duration ~horizon
        let e = DynamicScenario::from_json_str(
            r#"{"queries": 1000000,
                "phases": [{"kind": "burst", "start": 0, "period": 1,
                            "duration": 1000000, "ep": 0, "scenario": 1}]}"#,
        )
        .unwrap_err();
        assert!(chain(&e).contains("budget"), "{e:#}");
    }

    #[test]
    fn scaled_horizons_still_validate() {
        // the ROADMAP follow-up: horizons scale with --queries; every
        // builtin must survive shrinking and growing, and the identity
        // scale must be exact
        for name in BUILTIN_NAMES {
            let base = builtin(name).unwrap();
            assert_eq!(base.scaled(base.num_queries).unwrap(), base);
            for q in [50, 123, 2000, 10_000] {
                let s = base.scaled(q).unwrap_or_else(|e| {
                    panic!("{name} scaled to {q}: {e:#}")
                });
                assert_eq!(s.num_queries, q);
                assert_eq!(s.num_eps, base.num_eps);
                assert_eq!(s.phases.len(), base.phases.len());
                let sched = s.compile();
                assert_eq!(sched.num_queries(), q);
                assert!(
                    sched.interference_load() > 0.0,
                    "{name}@{q} lost all interference"
                );
            }
        }
    }

    #[test]
    fn scaling_preserves_shape_proportions() {
        // burst at half the horizon: the first burst lands at half the
        // query index with half the duration
        let s = builtin("burst").unwrap().scaled(1000).unwrap();
        match s.phases[0] {
            Phase::Burst { start, period, duration, ep, scenario } => {
                assert_eq!((start, period, duration), (50, 200, 75));
                assert_eq!((ep, scenario), (1, 9));
            }
            ref p => panic!("unexpected phase {p:?}"),
        }
    }

    #[test]
    fn adapted_remaps_eps_or_rejects_folds() {
        // burst's two phases (EP 1 and EP 3) fold onto EP 1 of a 2-EP
        // pipeline, where their windows are temporally disjoint — legal
        let s = builtin("burst").unwrap().adapted(200, 2).unwrap();
        assert_eq!(s.num_eps, 2);
        let sched = s.compile();
        assert_eq!(sched.num_eps, 2);
        assert!(sched.interference_load() > 0.0);
        // arrivals' tasks on EPs 0 and 2 collide when folded onto EP 0 —
        // the slot-exact overlap check must reject, with context
        let e = builtin("arrivals").unwrap().adapted(2000, 2).unwrap_err();
        let msg = chain(&e);
        assert!(msg.contains("overlap"), "{msg}");
        assert!(msg.contains("adapting"), "{msg}");
    }

    #[test]
    fn degenerate_scale_targets_error_with_context() {
        let base = builtin("ramp").unwrap();
        assert!(base.scaled(0).is_err());
        assert!(base.adapted(100, 0).is_err());
        // a 2-query horizon cannot hold a 3-level ramp: contextful error
        let e = base.scaled(2).unwrap_err();
        assert!(chain(&e).contains("adapting"), "{e:#}");
    }

    #[test]
    fn wall_clock_axis_parses_and_keeps_boundaries_absolute() {
        // a wall-clock scenario: phase fields in milliseconds, horizon
        // from horizon_ms; the compiled schedule indexes milliseconds
        let s = DynamicScenario::from_json_str(
            r#"{"name": "ms-burst", "eps": 2, "unit": "ms",
                "horizon_ms": 5000,
                "phases": [{"kind": "task", "start": 1000, "end": 3000,
                            "ep": 1, "scenario": 3}]}"#,
        )
        .unwrap();
        assert_eq!(s.axis, ScenarioAxis::Millis);
        assert_eq!(s.num_queries, 5000, "horizon is in ms");
        let sched = s.compile();
        assert_eq!(sched.at(999)[1], 0);
        assert_eq!(sched.at(1000)[1], 3);
        assert_eq!(sched.at(2999)[1], 3);
        assert_eq!(sched.at(3000)[1], 0);
        // adapting to a different query count must NOT move the
        // boundaries — wall-clock eras are admission-rate independent
        let a = s.adapted(50, 2).unwrap();
        assert_eq!(a, s);
        let a = s.adapted(100_000, 2).unwrap();
        assert_eq!(a.num_queries, 5000);
        assert_eq!(a.phases, s.phases);
        // ...while the EP remap still applies
        let folded = s.adapted(50, 1).unwrap();
        assert_eq!(folded.num_eps, 1);
        match folded.phases[0] {
            Phase::Task { start, end, ep, .. } => {
                assert_eq!((start, end, ep), (1000, 3000, 0));
            }
            ref p => panic!("unexpected phase {p:?}"),
        }
        // "horizon_ms" alone implies the ms axis
        let s2 = DynamicScenario::from_json_str(
            r#"{"name": "implied", "horizon_ms": 2000,
                "phases": [{"kind": "task", "start": 0, "end": 500,
                            "ep": 0, "scenario": 1}]}"#,
        )
        .unwrap();
        assert_eq!(s2.axis, ScenarioAxis::Millis);
    }

    #[test]
    fn wall_clock_axis_misuse_rejected() {
        let base = r#""phases": [{"kind": "task", "start": 0, "end": 10,
                                  "ep": 0, "scenario": 1}]"#;
        for (doc, needle) in [
            (
                format!(r#"{{"queries": 100, "horizon_ms": 100, {base}}}"#),
                "not both",
            ),
            (format!(r#"{{"unit": "ms", {base}}}"#), "requires"),
            (
                format!(r#"{{"unit": "queries", "horizon_ms": 50, {base}}}"#),
                "contradicts",
            ),
            (format!(r#"{{"unit": "hours", "queries": 100, {base}}}"#), "unit"),
        ] {
            let e = DynamicScenario::from_json_str(&doc).unwrap_err();
            assert!(chain(&e).contains(needle), "{doc}: {e:#}");
        }
    }

    #[test]
    fn load_missing_file_is_contextful_error() {
        let e = DynamicScenario::load("/nonexistent/odin/scenario.json")
            .unwrap_err();
        assert!(chain(&e).contains("scenario file"), "{e:#}");
    }

    #[test]
    fn resolve_prefers_builtin_then_file() {
        assert_eq!(resolve("burst").unwrap().name, "burst");
        let path = std::env::temp_dir().join("odin_dyn_scenario_test.json");
        std::fs::write(
            &path,
            r#"{"name": "from-file",
                "trace": [{"at": 0, "ep": 0, "scenario": 4}]}"#,
        )
        .unwrap();
        let s = resolve(path.to_str().unwrap()).unwrap();
        assert_eq!(s.name, "from-file");
        let _ = std::fs::remove_file(&path);
    }
}
