//! Real interference generators — an in-process reimplementation of the
//! two iBench stressors the paper co-locates with pipeline stages.
//!
//! Used by `odin bench-db` to measure the per-layer timing database under
//! genuine contention, and by examples/serve_pipeline.rs to disturb the
//! live serving path. Threads are pinned to the victim EP's cores when the
//! host has them (util::affinity degrades gracefully otherwise).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::util::affinity;

use super::scenarios::{Placement, Scenario, StressKind};

/// Working-set size of the memBW stressor: large enough to blow out any
/// L2/L3 and hit DRAM (iBench memBW streams ~100s of MiB; 64 MiB keeps
/// the sandbox friendly while still >> LLC).
const MEMBW_WORKING_SET: usize = 64 << 20;

/// A running stressor; dropping it stops and joins all threads.
pub struct Stressor {
    stop: Arc<AtomicBool>,
    threads: Vec<JoinHandle<()>>,
    /// Loop iterations completed — proves the stressor actually ran.
    pub work_done: Arc<AtomicU64>,
}

impl Stressor {
    /// Launch the stressor for `scenario`, pinning to `cores` when given.
    pub fn launch(scenario: Scenario, cores: Option<Vec<usize>>) -> Stressor {
        let stop = Arc::new(AtomicBool::new(false));
        let work_done = Arc::new(AtomicU64::new(0));
        let threads = (0..scenario.threads)
            .map(|i| {
                let stop = Arc::clone(&stop);
                let work = Arc::clone(&work_done);
                let cores = cores.clone();
                let kind = scenario.kind;
                std::thread::Builder::new()
                    .name(format!("odin-stress-{i}"))
                    .spawn(move || {
                        if let Some(c) = cores {
                            affinity::pin_current_thread(&c);
                        }
                        match kind {
                            StressKind::Cpu => cpu_loop(&stop, &work),
                            StressKind::MemBw => membw_loop(&stop, &work),
                        }
                    })
                    .expect("spawn stressor")
            })
            .collect();
        Stressor { stop, threads, work_done }
    }

    /// Launch the stressor against victim EP `ep` of an `num_eps`-stage
    /// pipeline whose EPs are `cores_per_ep` wide, deriving the core list
    /// from the scenario's [`Placement`] (see [`placement_cores`]) — so
    /// the stressor contends on exactly the cores the victim stage worker
    /// is pinned to, instead of callers passing `None` and stressing the
    /// whole machine.
    pub fn launch_on_ep(
        scenario: Scenario,
        ep: usize,
        num_eps: usize,
        cores_per_ep: usize,
    ) -> Stressor {
        let cores = placement_cores(scenario.placement, ep, num_eps, cores_per_ep);
        Stressor::launch(scenario, Some(cores))
    }

    pub fn stop(mut self) -> u64 {
        self.halt();
        self.work_done.load(Ordering::Relaxed)
    }

    fn halt(&mut self) {
        self.stop.store(true, Ordering::Release);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl Drop for Stressor {
    fn drop(&mut self) {
        self.halt();
    }
}

/// The cores a stressor should occupy for a given placement, mirroring
/// Table 1: `SameCores` timeshares the victim EP's own cores
/// ([`affinity::ep_cores`] — the same list the stage worker pins to);
/// `SameSocket` takes the core block just past the pipeline's EPs, so it
/// contends only on socket-shared resources. Hosts without those cores
/// degrade gracefully (pinning becomes a no-op and the threads roam).
pub fn placement_cores(
    placement: Placement,
    ep: usize,
    num_eps: usize,
    cores_per_ep: usize,
) -> Vec<usize> {
    match placement {
        Placement::SameCores => affinity::ep_cores(ep, cores_per_ep),
        Placement::SameSocket => affinity::ep_cores(num_eps, cores_per_ep),
    }
}

/// iBench CPU: dependent integer/float ALU chain, no memory traffic.
fn cpu_loop(stop: &AtomicBool, work: &AtomicU64) {
    let mut x: u64 = 0x9E3779B97F4A7C15;
    let mut f: f64 = 1.000000001;
    while !stop.load(Ordering::Acquire) {
        for _ in 0..4096 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            f = (f * 1.0000001).sqrt() + 0.5;
        }
        std::hint::black_box((x, f));
        work.fetch_add(1, Ordering::Relaxed);
    }
}

/// iBench memBW: pointer-free streaming writes+reads over a >LLC buffer.
fn membw_loop(stop: &AtomicBool, work: &AtomicU64) {
    let words = MEMBW_WORKING_SET / 8;
    let mut buf: Vec<u64> = vec![0; words];
    let mut seed: u64 = 1;
    while !stop.load(Ordering::Acquire) {
        // stride of one cache line (8 words) touches every line with
        // minimal ALU work — bandwidth-bound by construction
        let mut i = 0;
        while i < words {
            buf[i] = buf[i].wrapping_add(seed);
            i += 8;
        }
        seed = seed.wrapping_add(1);
        std::hint::black_box(buf[seed as usize % words]);
        work.fetch_add(1, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interference::scenarios::Placement;
    use std::time::Duration;

    fn scenario(kind: StressKind, threads: usize) -> Scenario {
        Scenario { id: 1, kind, threads, placement: Placement::SameCores }
    }

    #[test]
    fn cpu_stressor_does_work_and_stops() {
        let s = Stressor::launch(scenario(StressKind::Cpu, 2), None);
        std::thread::sleep(Duration::from_millis(50));
        let done = s.stop();
        assert!(done > 0, "cpu stressor made no progress");
    }

    #[test]
    fn membw_stressor_does_work_and_stops() {
        let s = Stressor::launch(scenario(StressKind::MemBw, 1), None);
        std::thread::sleep(Duration::from_millis(120));
        let done = s.stop();
        assert!(done > 0, "membw stressor made no progress");
    }

    #[test]
    fn drop_stops_threads() {
        let s = Stressor::launch(scenario(StressKind::Cpu, 1), Some(vec![0]));
        std::thread::sleep(Duration::from_millis(20));
        drop(s); // must join, not leak a spinning thread
    }

    #[test]
    fn placement_cores_match_victim_pinning() {
        // SameCores = the exact list the stage worker pins to
        assert_eq!(
            placement_cores(Placement::SameCores, 1, 4, 8),
            affinity::ep_cores(1, 8)
        );
        // SameSocket = the block past the pipeline's EPs, disjoint from
        // every victim EP
        let sock = placement_cores(Placement::SameSocket, 1, 4, 8);
        assert_eq!(sock, (32..40).collect::<Vec<_>>());
        for ep in 0..4 {
            let victim = affinity::ep_cores(ep, 8);
            assert!(sock.iter().all(|c| !victim.contains(c)));
        }
    }

    #[test]
    fn launch_on_ep_runs_and_stops() {
        let s = Stressor::launch_on_ep(scenario(StressKind::Cpu, 2), 0, 2, 1);
        std::thread::sleep(Duration::from_millis(30));
        assert!(s.stop() > 0);
    }
}
