//! Interference schedules: when, where, and which scenario.
//!
//! The paper's §4.2 grid: "frequency periods of 2, 10, and 100 queries and
//! duration 2, 10, and 100 queries" over a 4000-query window, with random
//! scenarios induced on random execution places. A schedule is expanded
//! ahead of time into a per-query → per-EP scenario map so simulator runs
//! are reproducible and O(1) per query.

use crate::util::Rng;

use super::scenarios::NUM_SCENARIOS;

/// Scenario id active on each EP (0 = no interference).
pub type EpScenarios = Vec<usize>;

/// A fully-expanded schedule: `state[q][ep]` = scenario id while query q
/// is being served.
#[derive(Clone, Debug)]
pub struct Schedule {
    pub num_eps: usize,
    states: Vec<EpScenarios>,
    /// Query indices at which the EP-state vector changed (rebalancing
    /// triggers are only possible here).
    pub change_points: Vec<usize>,
}

/// Parameters of the paper's random interference process.
#[derive(Clone, Copy, Debug)]
pub struct RandomInterference {
    /// A new interference event is drawn every `period` queries.
    pub period: usize,
    /// Each event keeps its scenario active for `duration` queries.
    pub duration: usize,
    /// Seed for the draw sequence.
    pub seed: u64,
    /// Probability that a draw actually places interference (the paper
    /// always places one; keep 1.0 to match).
    pub p_active: f64,
}

impl Schedule {
    /// The interference-free schedule.
    pub fn none(num_eps: usize, num_queries: usize) -> Schedule {
        Schedule {
            num_eps,
            states: vec![vec![0; num_eps]; num_queries.max(1)],
            change_points: Vec::new(),
        }
    }

    /// Expand the paper's random process: every `period` queries pick a
    /// random EP and a random scenario, active for `duration` queries
    /// (overwriting that EP's previous state; other EPs keep theirs).
    pub fn random(
        num_eps: usize,
        num_queries: usize,
        params: RandomInterference,
    ) -> Schedule {
        assert!(num_eps > 0 && num_queries > 0);
        assert!(params.period > 0 && params.duration > 0);
        let mut rng = Rng::new(params.seed);
        let mut states = Vec::with_capacity(num_queries);
        // expiry[ep] = query index when the current scenario ends
        let mut current = vec![0usize; num_eps];
        let mut expiry = vec![0usize; num_eps];
        let mut change_points = Vec::new();
        let mut prev: Option<EpScenarios> = None;
        for q in 0..num_queries {
            // expire finished events
            for ep in 0..num_eps {
                if current[ep] != 0 && q >= expiry[ep] {
                    current[ep] = 0;
                }
            }
            // draw a new event at each period boundary
            if q % params.period == 0 && rng.chance(params.p_active) {
                let ep = rng.below(num_eps);
                let scenario = 1 + rng.below(NUM_SCENARIOS);
                current[ep] = scenario;
                expiry[ep] = q + params.duration;
            }
            if prev.as_ref() != Some(&current) {
                change_points.push(q);
                prev = Some(current.clone());
            }
            states.push(current.clone());
        }
        // the very first entry is only a "change" if it has interference
        if states[0].iter().all(|&s| s == 0) && change_points.first() == Some(&0) {
            change_points.remove(0);
        }
        Schedule { num_eps, states, change_points }
    }

    /// Hand-built schedule from (start_query, ep, scenario_id, duration)
    /// events — used by the Fig. 3 timeline experiment.
    pub fn from_events(
        num_eps: usize,
        num_queries: usize,
        events: &[(usize, usize, usize, usize)],
    ) -> Schedule {
        let mut states = vec![vec![0usize; num_eps]; num_queries];
        for &(start, ep, scenario, duration) in events {
            assert!(ep < num_eps, "event EP {ep} out of range");
            assert!(scenario <= NUM_SCENARIOS);
            for q in start..(start + duration).min(num_queries) {
                states[q][ep] = scenario;
            }
        }
        let mut change_points = Vec::new();
        for q in 0..num_queries {
            if q > 0 && states[q] != states[q - 1] {
                change_points.push(q);
            }
        }
        Schedule { num_eps, states, change_points }
    }

    pub fn num_queries(&self) -> usize {
        self.states.len()
    }

    /// Scenario vector while query q is in flight (clamps past the end).
    pub fn at(&self, q: usize) -> &EpScenarios {
        &self.states[q.min(self.states.len() - 1)]
    }

    /// Index of the constant-state *run* containing `slot`: the number
    /// of change points at or before it. Every constructor records
    /// exactly the slots where the EP-state vector changes, so two slots
    /// with equal run index always carry an identical state vector (and
    /// clamping past the end, like [`at`](Self::at), stays in the last
    /// run — no change point lies beyond the horizon). The engine caches
    /// stage times keyed on this integer instead of content-comparing
    /// the O(num_eps) state vector every query.
    pub fn run_of(&self, slot: usize) -> usize {
        self.change_points.partition_point(|&c| c <= slot)
    }

    /// Fraction of (query, EP) slots that have interference — a sanity
    /// metric printed by experiment runners.
    pub fn interference_load(&self) -> f64 {
        let total = (self.states.len() * self.num_eps) as f64;
        let active: usize = self
            .states
            .iter()
            .map(|s| s.iter().filter(|&&x| x != 0).count())
            .sum();
        active as f64 / total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params(period: usize, duration: usize) -> RandomInterference {
        RandomInterference { period, duration, seed: 42, p_active: 1.0 }
    }

    #[test]
    fn none_schedule_is_clean() {
        let s = Schedule::none(4, 100);
        assert_eq!(s.interference_load(), 0.0);
        assert!(s.change_points.is_empty());
    }

    #[test]
    fn random_is_deterministic_per_seed() {
        let a = Schedule::random(4, 500, params(10, 10));
        let b = Schedule::random(4, 500, params(10, 10));
        for q in 0..500 {
            assert_eq!(a.at(q), b.at(q));
        }
    }

    #[test]
    fn different_seeds_differ() {
        let a = Schedule::random(4, 500, params(10, 10));
        let mut p = params(10, 10);
        p.seed = 43;
        let b = Schedule::random(4, 500, p);
        assert!((0..500).any(|q| a.at(q) != b.at(q)));
    }

    #[test]
    fn duration_respected() {
        // period 100, duration 2: interference lives exactly 2 queries
        let s = Schedule::random(4, 400, params(100, 2));
        for q in 0..400 {
            let active = s.at(q).iter().any(|&x| x != 0);
            let in_window = q % 100 < 2;
            assert_eq!(active, in_window, "q={q}");
        }
    }

    #[test]
    fn long_duration_keeps_interference_on() {
        // duration == period: interference is continuous on some EP
        let s = Schedule::random(2, 300, params(10, 10));
        let covered = (0..300)
            .filter(|&q| s.at(q).iter().any(|&x| x != 0))
            .count();
        assert_eq!(covered, 300);
    }

    #[test]
    fn scenario_ids_in_range() {
        let s = Schedule::random(4, 1000, params(2, 10));
        for q in 0..1000 {
            for &sc in s.at(q) {
                assert!(sc <= NUM_SCENARIOS);
            }
        }
    }

    #[test]
    fn from_events_places_and_expires() {
        let s = Schedule::from_events(4, 30, &[(5, 2, 7, 10)]);
        assert_eq!(s.at(4)[2], 0);
        assert_eq!(s.at(5)[2], 7);
        assert_eq!(s.at(14)[2], 7);
        assert_eq!(s.at(15)[2], 0);
        assert_eq!(s.change_points, vec![5, 15]);
    }

    #[test]
    fn change_points_match_state_transitions() {
        let s = Schedule::random(4, 2000, params(10, 5));
        for (i, &cp) in s.change_points.iter().enumerate() {
            assert!(cp > 0 || i == 0);
            if cp > 0 {
                assert_ne!(s.at(cp), s.at(cp - 1), "cp={cp}");
            }
        }
    }

    /// The invariant the engine's stage-time cache rests on: equal run
    /// index ⟺ identical state vector for every slot pair, across all
    /// three constructors (and past the clamped horizon).
    #[test]
    fn run_of_partitions_slots_into_constant_state_runs() {
        let schedules = [
            Schedule::none(4, 50),
            Schedule::random(4, 600, params(10, 5)),
            Schedule::from_events(4, 40, &[(5, 2, 7, 10), (20, 0, 3, 40)]),
        ];
        for s in &schedules {
            let horizon = s.num_queries();
            for q in 1..horizon + 10 {
                let same_run = s.run_of(q) == s.run_of(q - 1);
                assert_eq!(
                    same_run,
                    s.at(q) == s.at(q - 1),
                    "slot {q}: run index and state content disagree"
                );
            }
            // clamping: the tail shares the last slot's run
            assert_eq!(s.run_of(horizon + 1000), s.run_of(horizon - 1));
        }
    }

    #[test]
    fn interference_load_scales_with_duration() {
        let short = Schedule::random(4, 4000, params(100, 2));
        let long = Schedule::random(4, 4000, params(100, 100));
        assert!(long.interference_load() > short.interference_load());
    }
}
