//! The colocation scenario catalogue (paper Table 1).
//!
//! The paper builds 12 scenarios from the iBench `CPU` and `memBW`
//! stressors by varying thread count and core placement. The table itself
//! is an image in the paper; this reconstruction follows its prose
//! description exactly: two stressor kinds × thread counts {2, 4, 8} ×
//! placements {same cores as the pipeline stage, other cores of the same
//! socket} = 12 scenarios. Scenario 0 ("none") is the interference-free
//! column of the m×(n+1) database.

/// Stressor kind, mirroring the two iBench benchmarks the paper uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StressKind {
    /// iBench `CPU`: saturates the ALUs / pipeline ports.
    Cpu,
    /// iBench `memBW`: streams a large working set, saturating memory
    /// bandwidth and polluting the shared cache.
    MemBw,
}

/// Where the stressor threads are pinned relative to the victim stage.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Placement {
    /// Timeshares the exact cores of the pipeline stage (SMT siblings /
    /// same physical cores) — the harshest setting.
    SameCores,
    /// Other cores of the same socket: contends only on shared resources
    /// (LLC, memory controller).
    SameSocket,
}

/// One row of Table 1.
#[derive(Clone, Copy, Debug)]
pub struct Scenario {
    /// 1-based id; 0 is reserved for "no interference".
    pub id: usize,
    pub kind: StressKind,
    pub threads: usize,
    pub placement: Placement,
}

pub const NUM_SCENARIOS: usize = 12;

/// The full catalogue, ids 1..=12.
pub fn catalogue() -> Vec<Scenario> {
    let mut out = Vec::with_capacity(NUM_SCENARIOS);
    let mut id = 1;
    for kind in [StressKind::Cpu, StressKind::MemBw] {
        for placement in [Placement::SameCores, Placement::SameSocket] {
            for threads in [2, 4, 8] {
                out.push(Scenario { id, kind, threads, placement });
                id += 1;
            }
        }
    }
    out
}

impl Scenario {
    pub fn by_id(id: usize) -> Option<Scenario> {
        if id == 0 || id > NUM_SCENARIOS {
            return None;
        }
        Some(catalogue()[id - 1])
    }

    pub fn label(&self) -> String {
        format!(
            "{}_{}t_{}",
            match self.kind {
                StressKind::Cpu => "cpu",
                StressKind::MemBw => "membw",
            },
            self.threads,
            match self.placement {
                Placement::SameCores => "same",
                Placement::SameSocket => "socket",
            }
        )
    }

    /// Normalized contention pressures in [0, 1]: (cpu, mem).
    ///
    /// Drives the *synthetic* database (database::synth). Calibrated so
    /// the resulting slowdowns span the 1.1×–3× band the paper's Fig. 4
    /// shows for a VGG16 layer across the 12 scenarios.
    pub fn pressure(&self) -> (f64, f64) {
        let occupancy = self.threads as f64 / 8.0; // EPs are 8 cores wide
        let locality = match self.placement {
            Placement::SameCores => 1.0,
            Placement::SameSocket => 0.45,
        };
        match self.kind {
            StressKind::Cpu => (occupancy * locality, 0.15 * occupancy * locality),
            StressKind::MemBw => {
                // memBW hurts even from other cores (shared controller);
                // its cpu-port pressure is mild.
                let mem_locality = match self.placement {
                    Placement::SameCores => 1.0,
                    Placement::SameSocket => 0.75,
                };
                (0.2 * occupancy * locality, occupancy * mem_locality)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn twelve_scenarios_with_unique_ids() {
        let cat = catalogue();
        assert_eq!(cat.len(), NUM_SCENARIOS);
        for (i, s) in cat.iter().enumerate() {
            assert_eq!(s.id, i + 1);
        }
    }

    #[test]
    fn by_id_bounds() {
        assert!(Scenario::by_id(0).is_none());
        assert!(Scenario::by_id(13).is_none());
        assert_eq!(Scenario::by_id(1).unwrap().id, 1);
        assert_eq!(Scenario::by_id(12).unwrap().id, 12);
    }

    #[test]
    fn labels_unique() {
        let cat = catalogue();
        let mut labels: Vec<String> = cat.iter().map(|s| s.label()).collect();
        labels.sort();
        labels.dedup();
        assert_eq!(labels.len(), NUM_SCENARIOS);
    }

    #[test]
    fn pressure_monotone_in_threads() {
        let cat = catalogue();
        for w in cat.chunks(3) {
            // within a (kind, placement) group threads go 2,4,8
            let p: Vec<f64> = w.iter().map(|s| s.pressure().0 + s.pressure().1).collect();
            assert!(p[0] < p[1] && p[1] < p[2], "{w:?}");
        }
    }

    #[test]
    fn same_cores_harsher_than_socket() {
        for kind in [StressKind::Cpu, StressKind::MemBw] {
            let same = Scenario { id: 0, kind, threads: 8, placement: Placement::SameCores };
            let sock = Scenario { id: 0, kind, threads: 8, placement: Placement::SameSocket };
            let (c1, m1) = same.pressure();
            let (c2, m2) = sock.pressure();
            assert!(c1 + m1 > c2 + m2);
        }
    }

    #[test]
    fn pressures_bounded() {
        for s in catalogue() {
            let (c, m) = s.pressure();
            assert!((0.0..=1.0).contains(&c), "{s:?}");
            assert!((0.0..=1.0).contains(&m), "{s:?}");
        }
    }
}
