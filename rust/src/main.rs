//! `odin` — the leader binary.
//!
//! Subcommands:
//!   simulate     run one simulation window and print its summary; with
//!                --scenario <name|file> runs the online control loop
//!                against a dynamic interference scenario (odin + lls /
//!                oracle / static baselines, per-window JSON), driven
//!                closed- or open-loop via --workload, multi-tenant
//!                via --tenants (per-tenant SLOs, EDF queue), or
//!                multi-replica via --fleet <spec> (router + autoscaler)
//!   experiment   regenerate paper tables/figures (table1, fig1..fig10,
//!                summary, dynamic, openloop, fleet, predictive, or `all`)
//!   bench        run the in-process perf suite (simulated-throughput
//!                grid + fleet cell + baseline-vs-refactored pairs) and
//!                write the BENCH_<pr>.json trajectory artifact
//!   bench-db     measure the per-layer timing database on this host
//!                through the PJRT runtime, under real stressors
//!   verify       compile artifacts and check gold numerics
//!   serve        run the live pipeline server on N random queries; with
//!                --scenario <name|file> replays a dynamic interference
//!                scenario with real stressors and emits live_<name>.json;
//!                --tenants <name|file> serves a multi-tenant set through
//!                the SLO-aware queue; --fleet <spec> routes an open
//!                workload across real replicas on disjoint EP groups
//!   models       list built-in model specs

use odin::cli::{Args, CliError, Command};
use odin::coordinator::optimal_config;
use odin::database::measure::{measure, MeasureOpts};
use odin::database::synth::synthesize;
use odin::database::TimingDb;
use odin::experiments::dynamic::{
    run_scenario, run_scenario_workload, scenario_json, summary_line,
    DYN_SLO_LEVEL, DYN_WINDOW,
};
use odin::experiments::fleet::{
    fleet_cell, fleet_cell_json, FLEET_RATE_FRAC,
};
use odin::experiments::multitenant::{
    mt_scenario_json, run_tenant_scenario,
};
use odin::experiments::perf::{
    bench_doc, run_refactor_pairs, run_sim_throughput, PerfScale, BENCH_PR,
};
use odin::experiments::{self, ExpCtx};
use odin::interference::dynamic::{resolve, ScenarioAxis};
use odin::interference::{RandomInterference, Schedule};
use odin::json::Value;
use odin::models;
use odin::runtime::{
    ExecHandle, ExecService, Manifest, ModelRuntime, RuntimeTimer,
    SynthBackend, Tensor,
};
use odin::serving::{
    fleet_live_json, harness::LIVE_SLO_LEVEL, live_json, tenant, BatchPolicy,
    Fairness, FleetConfig, HarnessOpts, LiveDegrade, PipelineServer, Router,
    ScenarioDriver, ServeReport, ServerOpts, Workload, BATCH_SLACK_FACTOR,
};
use odin::simulator::{
    simulate, simulate_fleet_runs, simulate_policies_workload, FleetLoad,
    Policy, SimConfig, SimSummary,
};
use odin::util::affinity;
use odin::util::error::{OdinError, Result};
use odin::{bail, err};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match dispatch(&argv) {
        Ok(()) => 0,
        Err(e) => {
            if let Some(cli) = e.downcast_ref::<CliError>() {
                if matches!(cli, CliError::HelpRequested(_)) {
                    println!("{cli}");
                    0
                } else {
                    eprintln!("error: {cli}");
                    2
                }
            } else {
                eprintln!("error: {e:#}");
                1
            }
        }
    };
    std::process::exit(code);
}

fn usage() -> String {
    "odin — ODIN inference-pipeline coordinator (paper reproduction)\n\n\
     subcommands:\n\
       simulate     one simulation window; --scenario <name|file> runs the\n\
                    online loop against a dynamic interference scenario;\n\
                    --fleet <spec> routes over multiple pipeline replicas\n\
       experiment   regenerate paper artifacts: table1 fig1 fig3..fig10\n\
                    summary dynamic openloop multitenant batching fleet\n\
                    predictive all\n\
       bench        run the in-process perf suite (sim throughput grid +\n\
                    fleet cell + refactor pairs) and write the\n\
                    BENCH_<pr>.json trajectory artifact\n\
       bench-db     measure the per-layer timing database via PJRT\n\
       verify       compile artifacts + gold numerics check\n\
       serve        live pipeline server; --scenario <name|file> replays a\n\
                    dynamic scenario with real stressors (live_<name>.json)\n\
       models       list model specs\n\n\
     `odin <subcommand> --help` for flags"
        .to_string()
}

fn dispatch(argv: &[String]) -> Result<()> {
    let Some(sub) = argv.first() else {
        println!("{}", usage());
        return Ok(());
    };
    let rest = &argv[1..];
    match sub.as_str() {
        "simulate" => cmd_simulate(rest),
        "experiment" => cmd_experiment(rest),
        "bench" => cmd_bench(rest),
        "bench-db" => cmd_bench_db(rest),
        "verify" => cmd_verify(rest),
        "serve" => cmd_serve(rest),
        "models" => cmd_models(rest),
        "--help" | "-h" | "help" => {
            println!("{}", usage());
            Ok(())
        }
        other => bail!("unknown subcommand {other:?}\n{}", usage()),
    }
}

/// Timing database for `simulate` (both modes): synthesized from the
/// --model spec by default, loaded from --db when given.
fn load_sim_db(args: &Args) -> Result<TimingDb> {
    let spec = models::build(args.get("model"), args.usize("spatial")?)
        .ok_or_else(|| err!("unknown model {}", args.get("model")))?;
    Ok(if args.get("db").is_empty() {
        synthesize(&spec, args.u64("seed")?)
    } else {
        TimingDb::load(args.get("db")).map_err(OdinError::msg)?
    })
}

fn parse_policy(args: &Args) -> Result<Policy> {
    Ok(match args.get("policy") {
        "odin" => Policy::Odin { alpha: args.usize("alpha")? },
        "odin_pred" => Policy::OdinPred { alpha: args.usize("alpha")? },
        "lls" => Policy::Lls,
        "oracle" => Policy::Oracle,
        "static" => Policy::Static,
        other => bail!(
            "unknown policy {other:?} (odin|odin_pred|lls|oracle|static)"
        ),
    })
}

fn cmd_simulate(argv: &[String]) -> Result<()> {
    let cmd = Command::new("simulate", "run one simulation window")
        .flag("model", "vgg16", "vgg16 | resnet50 | resnet152")
        .flag("eps", "4", "number of execution places")
        .flag("queries", "4000", "queries in the window")
        .flag("policy", "odin", "odin | odin_pred | lls | oracle | static")
        .flag("alpha", "10", "ODIN exploration budget")
        .flag("period", "10", "interference frequency period (queries)")
        .flag("duration", "10", "interference duration (queries)")
        .flag("seed", "42", "rng seed")
        .flag("spatial", "64", "model input resolution")
        .opt("db", "timing database json (default: synthetic)")
        .opt(
            "scenario",
            "dynamic scenario (builtin name or JSON file); runs the online \
             loop for odin + lls/oracle/static baselines",
        )
        .opt(
            "workload",
            "arrival process for scenario mode: closed:<depth> | \
             poisson:<rate>qps[@seed] | trace:<file.json> (default: the \
             historical closed loop)",
        )
        .opt(
            "tenants",
            "multi-tenant set (builtin name or JSON file): merge the \
             tenants' workloads through the SLO-aware queue under \
             --scenario (default scenario: burst)",
        )
        .opt(
            "fleet",
            "fleet spec RxK[:router][:autoMIN..MAX] (e.g. 2x4:p2c): \
             route an open workload over R pipeline replicas of K EPs \
             each under --scenario (default: storm); router = jsq | p2c \
             | sticky",
        )
        .flag(
            "queue-cap",
            "256",
            "arrival-queue bound for open workloads (arrivals past it \
             are shed)",
        )
        .flag(
            "batch",
            "off",
            "batch former for open workloads in scenario mode: off | \
             fixed:<n> | deadline",
        )
        .flag(
            "fairness",
            "reported",
            "tenant fairness enforcement for --tenants: reported | wfq | \
             wfq+caps",
        )
        .flag("jobs", "1", "worker threads for the scenario policy sweep")
        .flag("out", "results", "output dir for scenario JSON ('' = none)")
        .switch("no-interference", "run a clean window");
    let args = cmd.parse(argv)?;
    if !args.get("fleet").is_empty() {
        return cmd_simulate_fleet(&args);
    }
    if !args.get("tenants").is_empty() {
        return cmd_simulate_tenants(&args);
    }
    if !args.get("scenario").is_empty() {
        return cmd_simulate_scenario(&args);
    }
    // the policy-sweep flags only exist in scenario mode; reject them
    // here rather than silently ignoring them
    if args.was_given("fairness") {
        bail!(
            "--fairness requires --tenants: fairness enforcement is a \
             property of the multi-tenant SLO queue"
        );
    }
    for flag in ["jobs", "out", "workload", "queue-cap", "batch"] {
        if args.was_given(flag) {
            bail!("--{flag} only applies to `simulate --scenario <name|file>`");
        }
    }
    let db = load_sim_db(&args)?;
    let eps = args.usize("eps")?;
    let queries = args.usize("queries")?;
    let schedule = if args.has("no-interference") {
        Schedule::none(eps, queries)
    } else {
        Schedule::random(
            eps,
            queries,
            RandomInterference {
                period: args.usize("period")?,
                duration: args.usize("duration")?,
                seed: args.u64("seed")?,
                p_active: 1.0,
            },
        )
    };
    let policy = parse_policy(&args)?;
    let r = simulate(&db, &schedule, &SimConfig::new(eps, policy));
    let s = SimSummary::of(&r);
    println!(
        "{}",
        s.row(&format!(
            "{}/{}/p{}d{}",
            args.get("model"),
            policy.label(),
            args.get("period"),
            args.get("duration")
        ))
    );
    println!(
        "final config {}  peak {:.2} q/s  interference load {:.1}%",
        r.final_config,
        r.peak_throughput,
        100.0 * schedule.interference_load()
    );
    Ok(())
}

/// `odin simulate --scenario <name|file>`: run the online control loop
/// against one dynamic scenario, with LLS, the exhaustive oracle, and a
/// static pipeline as baselines under the identical scenario stream —
/// and, with `--workload`, under the identical arrival timeline — and
/// emit the per-window JSON (byte-identical for every `--jobs` value).
fn cmd_simulate_scenario(args: &Args) -> Result<()> {
    let db = load_sim_db(args)?;
    // scenario mode fixes the EPs (from the scenario) and the policy set
    // (odin + all baselines); reject contradicting flags instead of
    // silently ignoring them. --queries is honored: it rescales the
    // scenario's horizon (phases keep their proportional shape) for
    // query-axis scenarios, and sizes the run for wall-clock ones.
    for flag in ["policy", "eps", "period", "duration"] {
        if !args.was_given(flag) {
            continue;
        }
        bail!(
            "--{flag} cannot be combined with --scenario: the scenario \
             file sets the EPs, and the online loop always runs odin + \
             lls/oracle/static under the identical stream (--queries \
             rescales the horizon)"
        );
    }
    if args.has("no-interference") {
        bail!("--no-interference cannot be combined with --scenario");
    }
    if args.was_given("fairness") {
        bail!(
            "--fairness requires --tenants: fairness enforcement is a \
             property of the multi-tenant SLO queue"
        );
    }
    let mut scenario = resolve(args.get("scenario"))?;
    if args.was_given("queries") {
        scenario = scenario.scaled(args.usize("queries")?)?;
    }
    let workload = if args.was_given("workload") {
        Some(Workload::parse(args.get("workload"))?)
    } else {
        None
    };
    if args.was_given("queue-cap")
        && !workload.as_ref().is_some_and(|w| w.is_open())
    {
        bail!(
            "--queue-cap only applies to an open --workload \
             (poisson:* or trace:*): closed loops never queue"
        );
    }
    let queries_run = match scenario.axis {
        ScenarioAxis::Queries => scenario.num_queries,
        ScenarioAxis::Millis => args.usize("queries")?,
    };
    let policies = [
        Policy::Odin { alpha: args.usize("alpha")? },
        Policy::Lls,
        Policy::Oracle,
        Policy::Static,
    ];
    let jobs = args.usize("jobs")?.max(1);
    // clamp like the serve path: a 0 cap must not trip the SimConfig
    // assert into a panic (and the shed report prints what actually ran)
    let queue_cap = args.usize("queue-cap")?.max(1);
    let batch = BatchPolicy::parse(args.get("batch"))?;
    if !batch.is_off() && !workload.as_ref().is_some_and(|w| w.is_open()) {
        bail!(
            "--batch {} requires an open --workload (poisson:* or \
             trace:*): closed admission has no arrival queue to batch \
             from",
            batch.spec()
        );
    }
    // no --workload on a query-axis scenario = the historical engine
    // path, bit-for-bit; everything else goes through the Workload API
    let (schedule, results) = match &workload {
        None if scenario.axis == ScenarioAxis::Queries => {
            run_scenario(&db, &scenario, &policies, jobs)
        }
        maybe => {
            let w = match maybe {
                Some(w) => w.clone(),
                None => Workload::closed(
                    odin::serving::workload::MAX_CLOSED_DEPTH,
                )?,
            };
            if batch.is_off() {
                run_scenario_workload(
                    &db,
                    &scenario,
                    &policies,
                    &w,
                    queries_run,
                    queue_cap,
                    jobs,
                )?
            } else {
                let schedule = scenario.compile();
                let cfgs: Vec<SimConfig> = policies
                    .iter()
                    .map(|&p| {
                        SimConfig::new(scenario.num_eps, p)
                            .with_window(DYN_WINDOW)
                            .with_queue_cap(queue_cap)
                            .with_batch(batch)
                    })
                    .collect();
                let results = simulate_policies_workload(
                    &db,
                    &schedule,
                    scenario.axis,
                    &cfgs,
                    &w,
                    queries_run,
                    jobs,
                )?;
                (schedule, results)
            }
        }
    };
    for (policy, r) in policies.iter().zip(&results) {
        let s = SimSummary::of(r);
        println!(
            "{}",
            s.row(&format!(
                "{}/{}/{}",
                args.get("model"),
                scenario.name,
                policy.label()
            ))
        );
        if !r.dropped_at.is_empty() {
            println!(
                "  {}: shed {} of {} offered arrivals (queue cap {})",
                policy.label(),
                r.dropped_at.len(),
                r.offered,
                queue_cap,
            );
        }
    }
    let doc_scenario = scenario_json(&scenario, &schedule, &policies, &results);
    println!(
        "{}",
        summary_line(&scenario.name, doc_scenario.get("summary"))
    );
    if !args.get("out").is_empty() {
        let dir = std::path::Path::new(args.get("out"));
        std::fs::create_dir_all(dir)?;
        let mut top = vec![
            ("model", Value::from(args.get("model"))),
            ("scenario", doc_scenario),
            ("slo_level", Value::from(DYN_SLO_LEVEL)),
            ("window", Value::from(DYN_WINDOW)),
            (
                "workload",
                Value::from(
                    workload
                        .as_ref()
                        .map(|w| w.spec().to_string())
                        .unwrap_or_else(|| "closed".to_string()),
                ),
            ),
        ];
        // conditional like the tenants bump: batch-off documents keep
        // their historical top-level key set byte-for-byte
        if !batch.is_off() {
            top.push(("batch", Value::from(batch.spec())));
        }
        let doc = Value::obj(top);
        let path = dir.join(format!("scenario_{}.json", scenario.name));
        odin::json::write_file(&path, &doc)?;
        println!("wrote {}", path.display());
    }
    Ok(())
}

/// `odin simulate --tenants <name|file>`: multi-tenant SLO-aware serving
/// in the simulator — the set's open-loop workloads merge into one
/// deterministic labeled stream, admission is earliest-deadline-first
/// within priority class, shedding is deadline-aware, and every policy
/// (odin + lls/oracle/static) faces the identical stream under the
/// --scenario interference timeline (default: burst). Emits
/// `tenants_<set>_<scenario>.json` with per-window `tenants` rows
/// schema-identical to the live path's.
fn cmd_simulate_tenants(args: &Args) -> Result<()> {
    let db = load_sim_db(args)?;
    for flag in ["policy", "eps", "period", "duration", "workload", "batch"] {
        if !args.was_given(flag) {
            continue;
        }
        bail!(
            "--{flag} cannot be combined with --tenants: the tenant set \
             owns the workloads, the scenario sets the EPs, the online \
             loop always runs odin + lls/oracle/static under the \
             identical stream, and the SLO queue interleaves tenants \
             with distinct deadlines (no batching)"
        );
    }
    if args.has("no-interference") {
        bail!("--no-interference cannot be combined with --tenants");
    }
    let tenants = tenant::resolve(args.get("tenants"))?;
    let mut scenario = if args.get("scenario").is_empty() {
        odin::interference::dynamic::builtin("burst")?
    } else {
        resolve(args.get("scenario"))?
    };
    if args.was_given("queries") {
        scenario = scenario.scaled(args.usize("queries")?)?;
    }
    let queries_run = match scenario.axis {
        ScenarioAxis::Queries => scenario.num_queries,
        ScenarioAxis::Millis => args.usize("queries")?,
    };
    let policies = [
        Policy::Odin { alpha: args.usize("alpha")? },
        Policy::Lls,
        Policy::Oracle,
        Policy::Static,
    ];
    let jobs = args.usize("jobs")?.max(1);
    let queue_cap = args.usize("queue-cap")?.max(1);
    let fairness = Fairness::parse(args.get("fairness"))?;
    let (schedule, results) = run_tenant_scenario(
        &db,
        &scenario,
        &tenants,
        &policies,
        queue_cap,
        fairness,
        queries_run,
        jobs,
    )?;
    let doc_scenario =
        mt_scenario_json(&scenario, &schedule, &tenants, &policies, &results);
    for p in doc_scenario.get("policies").as_arr().unwrap_or(&[]) {
        println!(
            "{}/{}: completed {} of {} offered, dropped {}, slo \
             violations {}, rebalances {}",
            tenants.name,
            p.get("policy").as_str().unwrap_or("?"),
            p.get("completed").as_usize().unwrap_or(0),
            p.get("offered").as_usize().unwrap_or(0),
            p.get("dropped").as_usize().unwrap_or(0),
            p.get("slo_violations").as_usize().unwrap_or(0),
            p.get("rebalances").as_usize().unwrap_or(0),
        );
        for t in p.get("tenants").as_arr().unwrap_or(&[]) {
            println!(
                "  {:<8} offered {:>5}  completed {:>5}  dropped {:>4}  \
                 viol {:>4}  queued {:>8.2}ms  share {:.2} (weight {:.2})",
                t.get("id").as_str().unwrap_or("?"),
                t.get("offered").as_usize().unwrap_or(0),
                t.get("completed").as_usize().unwrap_or(0),
                t.get("dropped").as_usize().unwrap_or(0),
                t.get("slo_violations").as_usize().unwrap_or(0),
                t.get("queued_ns").as_f64().unwrap_or(0.0) / 1e6,
                t.get("share").as_f64().unwrap_or(0.0),
                t.get("weight_share").as_f64().unwrap_or(0.0),
            );
        }
    }
    if !args.get("out").is_empty() {
        let dir = std::path::Path::new(args.get("out"));
        std::fs::create_dir_all(dir)?;
        let mut top = vec![
            ("model", Value::from(args.get("model"))),
            ("scenario", doc_scenario),
            ("slo_level", Value::from(DYN_SLO_LEVEL)),
            ("window", Value::from(DYN_WINDOW)),
        ];
        // conditional like the batch bump: reported-mode documents keep
        // their historical top-level key set byte-for-byte
        if fairness.enforced() {
            top.insert(0, ("fairness", Value::from(fairness.spec())));
        }
        let doc = Value::obj(top);
        let path = dir.join(format!(
            "tenants_{}_{}.json",
            tenants.name, scenario.name
        ));
        odin::json::write_file(&path, &doc)?;
        println!("wrote {}", path.display());
    }
    Ok(())
}

/// `odin simulate --fleet <spec>`: one fleet cell in the simulator — R
/// pipeline replicas over disjoint EP groups, a front-end router (JSQ /
/// power-of-two-choices / tenant-sticky) balancing an open arrival
/// stream on queue depth + pressure, per-replica online controllers, and
/// (with `:autoMIN..MAX`) the slow autoscaling outer loop. The scenario
/// (default: storm) is adapted to the fleet's whole EP pool. Emits
/// `fleet_<scenario>.json`, byte-identical for every `--jobs` value.
fn cmd_simulate_fleet(args: &Args) -> Result<()> {
    for flag in ["eps", "period", "duration", "batch"] {
        if !args.was_given(flag) {
            continue;
        }
        bail!(
            "--{flag} cannot be combined with --fleet: the fleet spec \
             sets replicas x EPs, and per-replica batching is not \
             supported on the fleet path"
        );
    }
    if args.has("no-interference") {
        bail!("--no-interference cannot be combined with --fleet");
    }
    let fairness = Fairness::parse(args.get("fairness"))?;
    if fairness.enforced() {
        bail!(
            "--fairness is not supported with --fleet: per-replica \
             queues run the reported (EDF-only) mode"
        );
    }
    let fleet = FleetConfig::parse(args.get("fleet"))?;
    let db = load_sim_db(args)?;
    let scenario = if args.get("scenario").is_empty() {
        odin::interference::dynamic::builtin("storm")?
    } else {
        resolve(args.get("scenario"))?
    };
    let policy = parse_policy(args)?;
    let queue_cap = args.usize("queue-cap")?.max(1);
    let queries = args.usize("queries")?;
    let seed = args.u64("seed")?;
    let load = if !args.get("tenants").is_empty() {
        FleetLoad::Tenants(tenant::resolve(args.get("tenants"))?)
    } else if args.was_given("workload") {
        FleetLoad::Open(Workload::parse(args.get("workload"))?)
    } else {
        // default stream: 2x one replica's interference-free peak, the
        // same overload regime the fleet experiment sweeps
        let k = fleet.eps_per_replica;
        let (_, bottleneck) = optimal_config(&db, &vec![0usize; k], k);
        FleetLoad::Open(Workload::poisson(FLEET_RATE_FRAC / bottleneck, seed)?)
    };
    let run =
        fleet_cell(&scenario, fleet, load, policy, queue_cap, queries, seed)?;
    let results = simulate_fleet_runs(
        &db,
        std::slice::from_ref(&run),
        args.usize("jobs")?.max(1),
    )?;
    let r = &results[0];
    println!(
        "{}/{}: offered {}  completed {}  dropped {}  queued {}  \
         achieved {:.2} q/s  peak replicas {}  scale events {}",
        scenario.name,
        run.fleet.spec(),
        r.offered,
        r.completed(),
        r.dropped(),
        r.queued_end,
        r.achieved_throughput(),
        r.peak_replicas(),
        r.scale_events.len(),
    );
    for (id, mt) in r.replicas.iter().enumerate() {
        println!(
            "  replica {id}: routed {:>6}  completed {:>6}  dropped \
             {:>5}  rebalances {:>3}",
            r.routed[id],
            mt.result.latencies.len(),
            mt.result.dropped_at.len(),
            mt.result.rebalances.len(),
        );
    }
    for e in &r.scale_events {
        println!(
            "  scale {} -> {} at arrival {} (t {:.2}s)",
            e.from, e.to, e.at_arrival, e.t
        );
    }
    if !args.get("out").is_empty() {
        let dir = std::path::Path::new(args.get("out"));
        std::fs::create_dir_all(dir)?;
        let doc = Value::obj(vec![
            ("cell", fleet_cell_json(&scenario.name, &run, r)),
            ("model", Value::from(args.get("model"))),
            ("queue_cap", Value::from(queue_cap)),
            ("slo_level", Value::from(DYN_SLO_LEVEL)),
            ("window", Value::from(DYN_WINDOW)),
        ]);
        let path = dir.join(format!("fleet_{}.json", scenario.name));
        odin::json::write_file(&path, &doc)?;
        println!("wrote {}", path.display());
    }
    Ok(())
}

fn cmd_experiment(argv: &[String]) -> Result<()> {
    let cmd = Command::new("experiment", "regenerate paper tables/figures")
        .positional(
            "id",
            "table1|fig1|fig3..fig10|summary|ablation|dynamic|openloop|multitenant|batching|fleet|predictive|all",
        )
        .flag("out", "results", "output directory ('' = stdout only)")
        .flag("queries", "4000", "queries per simulation window")
        .flag("seed", "42", "rng seed")
        .flag("spatial", "64", "model input resolution")
        .flag("jobs", "1", "worker threads for simulation sweeps (results are jobs-invariant)");
    let args = cmd.parse(argv)?;
    let id = args
        .positional(0)
        .ok_or_else(|| err!("missing experiment id"))?;
    let ctx = ExpCtx {
        out_dir: (!args.get("out").is_empty()).then(|| args.get("out").into()),
        seed: args.u64("seed")?,
        queries: args.usize("queries")?,
        spatial: args.usize("spatial")?,
        jobs: args.usize("jobs")?.max(1),
    };
    experiments::run(id, &ctx)
}

/// `odin bench`: run the shared perf suite (`experiments::perf`)
/// in-process — no cargo needed at runtime — and write the
/// machine-readable `BENCH_<pr>.json` trajectory artifact: the
/// sim-throughput rows (fig5 grid + the 4x4:p2c storm fleet cell, each
/// with simulated qps) plus the baseline-vs-refactored micro pairs.
fn cmd_bench(argv: &[String]) -> Result<()> {
    let cmd = Command::new(
        "bench",
        "run the perf suite, write the bench trajectory artifact",
    )
    .flag("out", "results", "output dir for BENCH_<pr>.json ('' = none)")
    .opt("filter", "only run cases whose name contains this substring")
    .switch("short", "CI smoke scale (equivalent to ODIN_BENCH_SHORT=1)");
    let args = cmd.parse(argv)?;
    let scale = if args.has("short") {
        PerfScale::short()
    } else {
        PerfScale::from_env()
    };
    let filter = (!args.get("filter").is_empty())
        .then(|| args.get("filter").to_string())
        .or_else(|| std::env::var("ODIN_BENCH_FILTER").ok());
    let mut b = odin::util::bench::Bench::with_filter(
        "sim_throughput",
        filter.clone(),
    );
    run_sim_throughput(&mut b, scale)?;
    let mut pb = odin::util::bench::Bench::with_filter("pairs", filter);
    let pairs = run_refactor_pairs(&mut pb);
    for p in &pairs {
        println!(
            "pair {}  baseline={:.0}ns  after={:.0}ns  speedup={:.2}x",
            p.path,
            p.baseline_ns,
            p.after_ns,
            p.baseline_ns / p.after_ns,
        );
    }
    if !args.get("out").is_empty() {
        let dir = std::path::Path::new(args.get("out"));
        std::fs::create_dir_all(dir)?;
        let doc = bench_doc(
            false,
            "measured in-process by `odin bench` on this host",
            &[("sim_throughput", b.rows()), ("pairs", pb.rows())],
            &pairs,
        );
        let path = dir.join(format!("BENCH_{BENCH_PR}.json"));
        odin::json::write_file(&path, &doc)?;
        println!("wrote {}", path.display());
    }
    Ok(())
}

fn cmd_bench_db(argv: &[String]) -> Result<()> {
    let cmd = Command::new("bench-db", "measure the per-layer timing database")
        .flag("model", "vgg16", "model artifacts to measure")
        .flag("out", "artifacts/db_measured.json", "output path")
        .flag("reps", "5", "timed repetitions per (unit, scenario)")
        .flag("artifacts", "artifacts", "artifact directory");
    let args = cmd.parse(argv)?;
    let manifest = Manifest::load(args.get("artifacts"))?;
    let model = manifest
        .model(args.get("model"))
        .ok_or_else(|| err!("{} not in artifacts", args.get("model")))?;
    eprintln!("compiling {} ({} units) ...", model.name, model.units.len());
    let rt = ModelRuntime::load(model)?;
    let mut timer = RuntimeTimer::new(&rt)?;
    eprintln!("measuring 13 columns x {} units ...", model.units.len());
    let opts = MeasureOpts {
        reps: args.usize("reps")?,
        warmup: 1,
        stress_cores: None,
    };
    let db = measure(&mut timer, &opts)?;
    db.save(args.get("out"))?;
    println!(
        "wrote {} ({} units, max slowdown {:.2}x)",
        args.get("out"),
        db.num_units(),
        db.max_slowdown()
    );
    Ok(())
}

fn cmd_verify(argv: &[String]) -> Result<()> {
    let cmd = Command::new("verify", "compile artifacts + gold numerics check")
        .flag("artifacts", "artifacts", "artifact directory")
        .flag("tol", "0.001", "max |delta| tolerance");
    let args = cmd.parse(argv)?;
    let manifest = Manifest::load(args.get("artifacts"))?;
    for model in &manifest.models {
        let rt = ModelRuntime::load(model)?;
        let (checked, worst) = rt.verify_gold(args.f64("tol")?)?;
        println!(
            "{}: {} units compiled, {checked} gold-verified, max |delta| = {worst:.2e}",
            model.name,
            model.units.len()
        );
    }
    println!("verify OK");
    Ok(())
}

fn cmd_serve(argv: &[String]) -> Result<()> {
    let cmd = Command::new("serve", "live pipeline server")
        .flag("model", "vgg16", "model to serve")
        .flag("queries", "24", "queries to serve (scenario horizons rescale)")
        .opt("eps", "pipeline stages (default 4, or the scenario's EPs)")
        .flag("alpha", "2", "ODIN exploration budget")
        .flag("threshold", "0.25", "monitor detection threshold")
        .flag(
            "admission-depth",
            "2",
            "bounded in-flight admission window (1 = lock-step)",
        )
        .flag("artifacts", "artifacts", "artifact directory (PJRT mode)")
        .opt(
            "scenario",
            "dynamic scenario (builtin name or JSON file): replay it live \
             with real stressors on the synthetic backend, emitting \
             live_<name>.json",
        )
        .opt(
            "workload",
            "arrival process for scenario mode: closed:<depth> | \
             poisson:<rate>qps[@seed] | trace:<file.json> (default: \
             closed at --admission-depth)",
        )
        .opt(
            "tenants",
            "multi-tenant set (builtin name or JSON file): replay the \
             tenants' merged workloads live through the SLO-aware queue \
             under --scenario (default scenario: burst)",
        )
        .opt(
            "fleet",
            "fleet spec RxK[:router] (e.g. 2x4:p2c, R <= 4): serve an \
             open --workload live across R real pipeline replicas on \
             disjoint EP core groups under --scenario (default: burst)",
        )
        .flag(
            "queue-cap",
            "256",
            "arrival-queue bound for open workloads (arrivals past it \
             are shed)",
        )
        .flag(
            "batch",
            "off",
            "batch former for open workloads in scenario mode: off | \
             fixed:<n> | deadline",
        )
        .flag(
            "fairness",
            "reported",
            "tenant fairness enforcement for --tenants: reported | wfq | \
             wfq+caps",
        )
        .flag("query-ms", "2", "synthetic per-query work budget, ms")
        .flag("spatial", "16", "model input resolution (scenario mode)")
        .flag(
            "cores-per-ep",
            "0",
            "cores per EP for pinning + stressor placement (0 = host/eps)",
        )
        .flag("out", "results", "output dir for live JSON ('' = none)")
        .switch(
            "auto-threshold",
            "re-derive the detection threshold from noise in quiet windows",
        )
        .switch(
            "proactive",
            "forecast-driven control in scenario mode: rebalance when the \
             predicted bottleneck would blow the SLO, before the monitor \
             confirms",
        )
        .switch(
            "degrade",
            "accuracy-degradation ladder in scenario mode (implies \
             --proactive): fall back to the thin model variant under \
             sustained predicted overload instead of shedding",
        );
    let args = cmd.parse(argv)?;
    if !args.get("fleet").is_empty() {
        return cmd_serve_fleet(&args);
    }
    if !args.get("tenants").is_empty() {
        return cmd_serve_tenants(&args);
    }
    if !args.get("scenario").is_empty() {
        return cmd_serve_scenario(&args);
    }
    // reject scenario-only flags instead of silently ignoring them
    // (audited against the full flag set: every flag that only scenario
    // mode reads — including the new workload surface — must fail fast
    // here, with was_given for value flags and has for switches)
    if args.was_given("fairness") {
        bail!(
            "--fairness requires --tenants: fairness enforcement is a \
             property of the multi-tenant SLO queue"
        );
    }
    for flag in [
        "out",
        "auto-threshold",
        "cores-per-ep",
        "query-ms",
        "spatial",
        "workload",
        "queue-cap",
        "batch",
        "proactive",
        "degrade",
    ] {
        if args.was_given(flag) || args.has(flag) {
            bail!("--{flag} only applies to `serve --scenario <name|file>`");
        }
    }
    let manifest = Manifest::load(args.get("artifacts"))?;
    let model = manifest
        .model(args.get("model"))
        .ok_or_else(|| err!("{} not in artifacts", args.get("model")))?;
    let eps = args.usize_opt("eps")?.unwrap_or(4);
    let service = ExecService::spawn(model.clone())?;
    let spec = models::build(&model.name, manifest.spatial).unwrap();
    let db = synthesize(&spec, 7);
    let (config, _) = optimal_config(&db, &vec![0usize; eps], eps);
    let opts = ServerOpts {
        num_eps: eps,
        alpha: args.usize("alpha")?,
        detect_threshold: args.f64("threshold")?,
        admission_depth: args.usize("admission-depth")?.max(1),
        ..ServerOpts::default()
    };
    let mut server = PipelineServer::new(service.handle(), config, opts);
    let n = args.usize("queries")?;
    let inputs: Vec<Tensor> = (0..n)
        .map(|i| Tensor::random(&model.input_shape, i as u64, 1.0))
        .collect();
    let t0 = std::time::Instant::now();
    let done = server.serve(inputs)?;
    ServeReport::of(&done, t0.elapsed().as_secs_f64()).print("serve");
    println!("final config {}", server.config());
    Ok(())
}

/// `odin serve --scenario <name|file>`: replay a dynamic interference
/// scenario against the *live* pipeline server — real stage workers
/// pinned to EP cores, real iBench-style stressors launched and stopped
/// at phase boundaries on the victim EP's cores, the online
/// monitor→detect→rebalance loop closing over measured stage times — and
/// emit `live_<name>.json` whose per-window rows share the simulator's
/// exact window schema (diff it against `scenario_<name>.json`).
fn cmd_serve_scenario(args: &Args) -> Result<()> {
    if args.was_given("fairness") {
        bail!(
            "--fairness requires --tenants: fairness enforcement is a \
             property of the multi-tenant SLO queue"
        );
    }
    let base = resolve(args.get("scenario"))?;
    let queries = args.usize("queries")?;
    let eps = args.usize_opt("eps")?.unwrap_or(base.num_eps);
    let scenario = base.adapted(queries, eps)?;
    // the workload drives admission: closed:<depth> takes over the
    // admission window (contradicting --admission-depth is an error, not
    // a silent pick), open workloads replay arrivals through the bounded
    // queue at the --admission-depth in-flight window
    let workload = if args.was_given("workload") {
        Workload::parse(args.get("workload"))?
    } else {
        Workload::closed(args.usize("admission-depth")?.max(1))?
    };
    let mut depth = args.usize("admission-depth")?.max(1);
    if let Some(d) = workload.closed_depth() {
        if args.was_given("workload")
            && args.was_given("admission-depth")
            && d != depth
        {
            bail!(
                "--admission-depth {depth} contradicts --workload \
                 closed:{d}; give one of them"
            );
        }
        depth = d;
    }
    if args.was_given("queue-cap") && !workload.is_open() {
        bail!(
            "--queue-cap only applies to an open --workload \
             (poisson:* or trace:*): closed loops never queue"
        );
    }
    let batch = BatchPolicy::parse(args.get("batch"))?;
    if !batch.is_off() && !workload.is_open() {
        bail!(
            "--batch {} requires an open --workload (poisson:* or \
             trace:*): closed admission has no arrival queue to batch \
             from",
            batch.spec()
        );
    }
    let spec = models::build(args.get("model"), args.usize("spatial")?)
        .ok_or_else(|| err!("unknown model {}", args.get("model")))?;
    let backend = SynthBackend::new(&spec, args.f64("query-ms")?);
    let shape = backend.input_shape();
    let db = synthesize(&spec, 7);
    let (config, _) = optimal_config(&db, &vec![0usize; eps], eps);
    let mut cores_per_ep = args.usize("cores-per-ep")?;
    if cores_per_ep == 0 {
        cores_per_ep = (affinity::num_cpus() / eps).max(1);
    }
    // --proactive limit: the live SLO target on the bottleneck stage.
    // Clean peak throughput ≈ eps / query budget (busy-work splits
    // across stages by FLOPs), and a window violates the SLO when
    // sustained throughput < level × peak — i.e. when the bottleneck
    // stage exceeds 1 / (level × peak).
    let proactive = (args.has("proactive") || args.has("degrade")).then(
        || args.f64("query-ms").unwrap_or(2.0) / 1e3 / eps as f64
            / LIVE_SLO_LEVEL,
    );
    let degrade = if args.has("degrade") {
        let name = args.get("model");
        let thin = models::thin_variant_of(name).ok_or_else(|| {
            err!("--degrade: model {name} has no thin variant")
        })?;
        Some(LiveDegrade {
            thin_scale: 1.0 / models::THIN_FLOP_DIV as f64,
            full_accuracy: models::accuracy_proxy(name).unwrap_or(1.0),
            thin_accuracy: models::accuracy_proxy(thin).unwrap_or(0.85),
        })
    } else {
        None
    };
    let opts = ServerOpts {
        num_eps: eps,
        cores_per_ep,
        alpha: args.usize("alpha")?,
        detect_threshold: args.f64("threshold")?,
        admission_depth: depth,
        queue_cap: args.usize("queue-cap")?.max(1),
        proactive,
        degrade,
        ..ServerOpts::default()
    };
    let mut server = PipelineServer::new(ExecHandle::synthetic(backend), config, opts);
    let driver = ScenarioDriver::new(
        scenario,
        HarnessOpts {
            auto_threshold: args.has("auto-threshold"),
            cores_per_ep,
            batch,
            // uniform per-query slack: the same 8x headroom factor the
            // simulator grants over the clean serial latency, scaled to
            // the synthetic per-query work budget
            batch_slack_s: if batch.is_off() {
                0.0
            } else {
                BATCH_SLACK_FACTOR * args.f64("query-ms")? / 1e3
            },
            ..HarnessOpts::default()
        },
    );
    let inputs: Vec<Tensor> = (0..queries)
        .map(|i| Tensor::random(&shape, i as u64, 1.0))
        .collect();
    let run = driver.run_workload(&mut server, inputs, &workload)?;
    run.report.print(&format!("live/{}", driver.scenario().name));
    println!(
        "workload {}  offered {}  dropped {}  rebalances {}  serial \
         probes {}  stressor launches {} (work {})  threshold {:.3}  \
         final config {}",
        run.workload,
        run.offered,
        run.dropped,
        run.rebalance_log.len(),
        run.rebalance_log.iter().map(|e| e.trials).sum::<usize>(),
        run.stressor_launches,
        run.stressor_work,
        run.final_threshold,
        run.final_config,
    );
    if !args.get("out").is_empty() {
        let dir = std::path::Path::new(args.get("out"));
        std::fs::create_dir_all(dir)?;
        let doc = live_json(&driver, &run, args.get("model"), depth);
        let path = dir.join(format!("live_{}.json", driver.scenario().name));
        odin::json::write_file(&path, &doc)?;
        println!("wrote {}", path.display());
    }
    Ok(())
}

/// `odin serve --tenants <name|file>`: the live multi-tenant path — the
/// tenant set's merged arrival stream replays on the wall clock through
/// the server's SLO-aware queue (EDF within priority class, deadline-
/// aware shedding), under the --scenario stressor timeline (default:
/// burst), and `live_<scenario>.json` gains per-tenant totals plus the
/// per-window `tenants` rows — schema-identical to the simulator's
/// `odin simulate --tenants` document.
fn cmd_serve_tenants(args: &Args) -> Result<()> {
    if args.was_given("workload") {
        bail!(
            "--workload cannot be combined with --tenants: each tenant \
             of the set owns its workload"
        );
    }
    if args.was_given("batch") {
        bail!(
            "--batch cannot be combined with --tenants: the SLO queue \
             interleaves tenants with distinct deadlines, so a batch \
             former has no single deadline to size against"
        );
    }
    if args.has("proactive") || args.has("degrade") {
        bail!(
            "--proactive/--degrade are single-pipeline controls: the \
             multi-tenant queue has no per-tenant forecaster"
        );
    }
    let tenants = tenant::resolve(args.get("tenants"))?;
    let base = if args.get("scenario").is_empty() {
        odin::interference::dynamic::builtin("burst")?
    } else {
        resolve(args.get("scenario"))?
    };
    let queries = args.usize("queries")?;
    let eps = args.usize_opt("eps")?.unwrap_or(base.num_eps);
    let scenario = base.adapted(queries, eps)?;
    let spec = models::build(args.get("model"), args.usize("spatial")?)
        .ok_or_else(|| err!("unknown model {}", args.get("model")))?;
    let backend = SynthBackend::new(&spec, args.f64("query-ms")?);
    let shape = backend.input_shape();
    let db = synthesize(&spec, 7);
    let (config, _) = optimal_config(&db, &vec![0usize; eps], eps);
    let mut cores_per_ep = args.usize("cores-per-ep")?;
    if cores_per_ep == 0 {
        cores_per_ep = (affinity::num_cpus() / eps).max(1);
    }
    let depth = args.usize("admission-depth")?.max(1);
    let fairness = Fairness::parse(args.get("fairness"))?;
    let opts = ServerOpts {
        num_eps: eps,
        cores_per_ep,
        alpha: args.usize("alpha")?,
        detect_threshold: args.f64("threshold")?,
        admission_depth: depth,
        queue_cap: args.usize("queue-cap")?.max(1),
        fairness,
        ..ServerOpts::default()
    };
    let mut server =
        PipelineServer::new(ExecHandle::synthetic(backend), config, opts);
    let driver = ScenarioDriver::new(
        scenario,
        HarnessOpts {
            auto_threshold: args.has("auto-threshold"),
            cores_per_ep,
            ..HarnessOpts::default()
        },
    );
    let inputs: Vec<Tensor> = (0..queries)
        .map(|i| Tensor::random(&shape, i as u64, 1.0))
        .collect();
    let run = driver.run_tenants(&mut server, inputs, &tenants)?;
    run.report
        .print(&format!("live/{}/{}", driver.scenario().name, tenants.name));
    for t in &run.tenant_totals {
        println!(
            "  {:<8} offered {:>5}  completed {:>5}  dropped {:>4}  \
             viol {:>4}  queued {:>8.2}ms  service {:>8.2}ms",
            t.id,
            t.offered,
            t.completed,
            t.dropped,
            t.slo_violations,
            t.queued_ns / 1e6,
            t.service_ns / 1e6,
        );
    }
    println!(
        "workload {}  offered {}  dropped {}  rebalances {}  stressor \
         launches {} (work {})  final config {}",
        run.workload,
        run.offered,
        run.dropped,
        run.rebalance_log.len(),
        run.stressor_launches,
        run.stressor_work,
        run.final_config,
    );
    if !args.get("out").is_empty() {
        let dir = std::path::Path::new(args.get("out"));
        std::fs::create_dir_all(dir)?;
        let doc = live_json(&driver, &run, args.get("model"), depth);
        let path = dir.join(format!("live_{}.json", driver.scenario().name));
        odin::json::write_file(&path, &doc)?;
        println!("wrote {}", path.display());
    }
    Ok(())
}

/// `odin serve --fleet <spec>`: live fleet serving — R real
/// [`PipelineServer`] replicas, each with its own stage workers pinned to
/// a disjoint EP core group (`ep_offset = r * k`), its own bounded queue
/// and online controller, behind one front-end router balancing an open
/// arrival stream on instantaneous depth + queue pressure, with one
/// fleet-wide stressor rack replaying the --scenario timeline. Emits
/// `fleet_live_<scenario>.json`, whose per-replica rows and
/// replica-stamped windows share the fleet simulator's schema.
fn cmd_serve_fleet(args: &Args) -> Result<()> {
    if !args.get("tenants").is_empty() {
        bail!(
            "--tenants cannot be combined with --fleet on the live path: \
             the fleet router drives a single open workload"
        );
    }
    if args.was_given("fairness") {
        bail!(
            "--fairness requires --tenants: fairness enforcement is a \
             property of the multi-tenant SLO queue"
        );
    }
    if args.was_given("batch") {
        bail!("--batch is not supported on the fleet path");
    }
    if args.has("proactive") || args.has("degrade") {
        bail!(
            "--proactive/--degrade are single-pipeline controls: fleet \
             replicas run the reactive loop"
        );
    }
    if args.was_given("eps") {
        bail!("--eps cannot be combined with --fleet: the fleet spec \
               sets replicas x EPs");
    }
    let fleet = FleetConfig::parse(args.get("fleet"))?;
    if fleet.autoscale.is_some() {
        bail!(
            "autoscaling (:autoMIN..MAX) is simulator-only; the live \
             fleet serves a fixed replica count"
        );
    }
    if fleet.replicas > 4 {
        bail!(
            "live fleet supports at most 4 replicas (got {}): each one \
             spawns real stage workers on its own EP core group",
            fleet.replicas
        );
    }
    let workload = if args.was_given("workload") {
        Workload::parse(args.get("workload"))?
    } else {
        bail!(
            "serve --fleet needs an open --workload (e.g. \
             poisson:200qps): routing balances an arrival timeline"
        );
    };
    let base = if args.get("scenario").is_empty() {
        odin::interference::dynamic::builtin("burst")?
    } else {
        resolve(args.get("scenario"))?
    };
    let queries = args.usize("queries")?;
    let k = fleet.eps_per_replica;
    let total_eps = fleet.total_eps();
    let scenario = base.adapted(queries, total_eps)?;
    let spec = models::build(args.get("model"), args.usize("spatial")?)
        .ok_or_else(|| err!("unknown model {}", args.get("model")))?;
    let db = synthesize(&spec, 7);
    let (config, _) = optimal_config(&db, &vec![0usize; k], k);
    let mut cores_per_ep = args.usize("cores-per-ep")?;
    if cores_per_ep == 0 {
        cores_per_ep = (affinity::num_cpus() / total_eps).max(1);
    }
    let depth = args.usize("admission-depth")?.max(1);
    let mut servers: Vec<PipelineServer> = (0..fleet.replicas)
        .map(|r| {
            let backend = SynthBackend::new(&spec, args.f64("query-ms")?);
            PipelineServer::new(
                ExecHandle::synthetic(backend),
                config.clone(),
                ServerOpts {
                    num_eps: k,
                    cores_per_ep,
                    alpha: args.usize("alpha")?,
                    detect_threshold: args.f64("threshold")?,
                    admission_depth: depth,
                    queue_cap: args.usize("queue-cap")?.max(1),
                    ep_offset: r * k,
                    ..ServerOpts::default()
                },
            )
        })
        .collect();
    let shape = SynthBackend::new(&spec, args.f64("query-ms")?).input_shape();
    let driver = ScenarioDriver::new(
        scenario,
        HarnessOpts {
            auto_threshold: args.has("auto-threshold"),
            cores_per_ep,
            ..HarnessOpts::default()
        },
    );
    let mut router = Router::new(fleet.router, 42);
    let inputs: Vec<Tensor> = (0..queries)
        .map(|i| Tensor::random(&shape, i as u64, 1.0))
        .collect();
    let run = driver.run_fleet(&mut servers, inputs, &workload, &mut router)?;
    println!(
        "live/{}/{}: workload {}  offered {}  completed {}  dropped {}  \
         stressor launches {} (work {})  wall {:.2}s",
        driver.scenario().name,
        fleet.spec(),
        run.workload,
        run.offered,
        run.completed(),
        run.dropped(),
        run.stressor_launches,
        run.stressor_work,
        run.wall_seconds,
    );
    for rep in &run.replicas {
        println!(
            "  replica {}: routed {:>5}  completed {:>5}  dropped {:>4}  \
             rebalances {:>3}  final config {}",
            rep.id,
            rep.routed,
            rep.completed,
            rep.dropped,
            rep.rebalances,
            rep.final_config,
        );
    }
    if !args.get("out").is_empty() {
        let dir = std::path::Path::new(args.get("out"));
        std::fs::create_dir_all(dir)?;
        let doc =
            fleet_live_json(&driver, &run, args.get("model"), &fleet.spec());
        let path = dir
            .join(format!("fleet_live_{}.json", driver.scenario().name));
        odin::json::write_file(&path, &doc)?;
        println!("wrote {}", path.display());
    }
    Ok(())
}

fn cmd_models(_argv: &[String]) -> Result<()> {
    for name in models::MODEL_NAMES {
        let m = models::build(name, 64).unwrap();
        println!(
            "{name:<10} {:>3} units  {:>7.2} GFLOP/query  (spatial 64)",
            m.num_units(),
            m.total_flops() as f64 / 1e9
        );
    }
    Ok(())
}
