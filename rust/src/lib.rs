//! # ODIN — Overcoming Dynamic Interference in iNference pipelines
//!
//! Reproduction of Soomro, Papadopoulou & Pericàs (Euro-Par 2023) as a
//! three-layer rust + JAX + Pallas serving stack:
//!
//! * **L3 (this crate)** — the paper's contribution: an online pipeline-
//!   stage rebalancer ([`coordinator::odin`]) plus the serving runtime it
//!   lives in: execution places, a bind-to-stage pipeline server
//!   ([`serving`]), a PJRT artifact runtime ([`runtime`]), the
//!   interference machinery ([`interference`]) and the discrete-event
//!   simulator ([`simulator`]) that regenerates every figure of the paper.
//! * **L2/L1 (python, build-time only)** — JAX CNN models whose units are
//!   Pallas kernels, AOT-lowered to HLO text artifacts this crate loads.
//!
//! Entry points: the `odin` binary (`rust/src/main.rs`), the examples in
//! `examples/`, and the per-figure benches in `rust/benches/`.

// `EpScenarios` is a semantically-owned `Vec<usize>` alias that crosses
// many APIs by reference; rewriting those signatures to `&[usize]` would
// break `Schedule::at` callers that rely on the owned alias.
#![allow(clippy::ptr_arg)]

pub mod cli;
pub mod coordinator;
pub mod database;
pub mod experiments;
pub mod interference;
pub mod json;
pub mod models;
pub mod pipeline;
pub mod runtime;
pub mod serving;
pub mod simulator;
pub mod util;
