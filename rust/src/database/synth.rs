//! Synthetic timing database, calibrated to the paper's observations.
//!
//! The base (interference-free) time of a unit follows a simple roofline:
//! compute term (FLOPs / effective FLOP rate) + memory term (weight +
//! activation bytes / effective bandwidth). Interference scales the two
//! terms separately using the Table-1 scenario pressures:
//!
//!   t(u, s) = t_c(u) · (1 + A·cpu_press(s)) + t_m(u) · (1 + B·mem_press(s))
//!
//! with A, B calibrated so the per-layer slowdowns span the ≈1.1×–3×
//! band of the paper's Fig. 4. A small deterministic per-(unit, scenario)
//! jitter keeps rows from being exact multiples of each other (as real
//! measurements never are) without breaking reproducibility.

use crate::interference::{catalogue, NUM_SCENARIOS};
use crate::models::ModelSpec;
use crate::util::Rng;

use super::TimingDb;

/// Effective per-EP compute rate (FLOP/s). An 8-core EP of the paper's
/// i9-12900K sustains a few hundred GFLOP/s on tuned f32 conv kernels;
/// 50 GFLOP/s reflects the untuned single-stream path and only sets the
/// absolute scale — every paper metric is relative.
const EFF_FLOPS: f64 = 50e9;
/// Effective memory bandwidth per EP (B/s).
const EFF_BW: f64 = 12e9;
/// CPU-pressure slowdown gain (calibrated to Fig. 4's upper band).
const GAIN_CPU: f64 = 1.9;
/// Memory-pressure slowdown gain.
const GAIN_MEM: f64 = 2.1;
/// Deterministic jitter amplitude (fraction of the scenario time).
const JITTER: f64 = 0.04;

/// Synthesize the m×(n+1) database for `model`.
pub fn synthesize(model: &ModelSpec, seed: u64) -> TimingDb {
    let mut rng = Rng::new(seed ^ 0x0D1);
    let cat = catalogue();
    let mut times = Vec::with_capacity(model.units.len());
    for u in &model.units {
        let w_c = u.kind.compute_intensity();
        let bytes = 4.0 * (u.param_elems + u.act_elems) as f64;
        // Split the base time into compute-bound and memory-bound parts.
        let t_compute = u.flops as f64 / EFF_FLOPS;
        let t_memory = bytes / EFF_BW;
        let base = t_compute + t_memory;
        let mut row = Vec::with_capacity(NUM_SCENARIOS + 1);
        row.push(base);
        for s in &cat {
            let (cp, mp) = s.pressure();
            // compute-heavy units feel CPU pressure more, memory-heavy
            // units feel bandwidth pressure more
            let t = t_compute * (1.0 + GAIN_CPU * cp * (0.5 + w_c))
                + t_memory * (1.0 + GAIN_MEM * mp * (1.5 - w_c));
            // deterministic positive jitter (never below baseline)
            let jitter = 1.0 + JITTER * rng.f64();
            row.push((t * jitter).max(base));
        }
        times.push(row);
    }
    TimingDb::new(
        model.name.clone(),
        model.units.iter().map(|u| u.name.clone()).collect(),
        times,
        "synthetic",
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;

    #[test]
    fn synthesize_is_deterministic() {
        let m = models::vgg16(64);
        assert_eq!(synthesize(&m, 1), synthesize(&m, 1));
    }

    #[test]
    fn different_seed_changes_jitter_only_slightly() {
        let m = models::vgg16(64);
        let a = synthesize(&m, 1);
        let b = synthesize(&m, 2);
        for u in 0..a.num_units() {
            // identical baselines
            assert_eq!(a.base_time(u), b.base_time(u));
            for s in 1..=NUM_SCENARIOS {
                let ra = a.time(u, s) / a.base_time(u);
                let rb = b.time(u, s) / b.base_time(u);
                assert!((ra - rb).abs() / ra < 0.1, "u={u} s={s}");
            }
        }
    }

    #[test]
    fn slowdowns_in_fig4_band() {
        // Fig 4: across the 12 scenarios a VGG16 layer sees roughly
        // 1.05x .. 3x slowdowns. Check the synthetic band is comparable.
        let db = synthesize(&models::vgg16(64), 7);
        let max = db.max_slowdown();
        assert!(max > 1.8 && max < 4.0, "max slowdown {max}");
        // the mildest scenario must still slow things a little
        for u in 0..db.num_units() {
            let min = (1..=NUM_SCENARIOS)
                .map(|s| db.time(u, s) / db.base_time(u))
                .fold(f64::INFINITY, f64::min);
            assert!(min >= 1.0, "u={u} min {min}");
            assert!(min < 1.5, "u={u} mildest scenario too harsh: {min}");
        }
    }

    #[test]
    fn validates_for_all_models() {
        for name in models::MODEL_NAMES {
            let m = models::build(name, 64).unwrap();
            let db = synthesize(&m, 3);
            db.validate().unwrap();
            assert_eq!(db.num_units(), m.num_units());
        }
    }

    #[test]
    fn dense_units_more_membw_sensitive_than_conv() {
        let m = models::vgg16(64);
        let db = synthesize(&m, 5);
        // scenario 10 = membw 8 threads same cores (heaviest memory)
        let membw_heavy = 6; // cpu rows are 1..=6, membw 7..=12; pick 3rd membw = id 9
        let conv = 4; // conv3_1
        let fc = 14; // fc2
        let conv_ratio = db.time(conv, 6 + 3) / db.base_time(conv);
        let fc_ratio = db.time(fc, 6 + 3) / db.base_time(fc);
        assert!(
            fc_ratio > conv_ratio,
            "fc {fc_ratio} vs conv {conv_ratio} (scenario {membw_heavy})"
        );
    }
}
