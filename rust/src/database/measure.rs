//! Measured timing database: run each AOT unit through a timer, alone and
//! under each Table-1 stressor (paper §3.3, "we first collect the
//! execution time of the m individual network layers … executing alone …
//! [then] alongside co-located applications").
//!
//! Decoupled from the PJRT runtime through the [`UnitTimer`] trait so this
//! module stays testable without artifacts; `runtime::executor` implements
//! the trait for real HLO executables.

use crate::interference::{catalogue, Scenario, Stressor};
use crate::util::affinity;
use crate::util::error::Result;

use super::TimingDb;

/// Something that can execute unit `u` once and report seconds.
pub trait UnitTimer {
    fn num_units(&self) -> usize;
    fn unit_name(&self, u: usize) -> String;
    fn model_name(&self) -> String;
    /// Execute unit `u` once, end to end, returning elapsed seconds.
    fn time_unit(&mut self, u: usize) -> Result<f64>;
}

/// Measurement parameters.
#[derive(Clone, Debug)]
pub struct MeasureOpts {
    /// Timed repetitions per (unit, scenario); the *minimum* is kept
    /// (standard practice to reject scheduler noise in the baseline
    /// column) while interference columns keep the *median* (the noise
    /// there IS the signal).
    pub reps: usize,
    pub warmup: usize,
    /// Cores the stressor threads get pinned to (None ⇒ unpinned).
    pub stress_cores: Option<Vec<usize>>,
}

impl Default for MeasureOpts {
    fn default() -> Self {
        MeasureOpts { reps: 7, warmup: 2, stress_cores: None }
    }
}

/// Measure the full m×(n+1) database.
pub fn measure(timer: &mut dyn UnitTimer, opts: &MeasureOpts) -> Result<TimingDb> {
    let scenarios = catalogue();
    let m = timer.num_units();
    let mut times = vec![Vec::with_capacity(scenarios.len() + 1); m];

    // Column 0: alone.
    for u in 0..m {
        times[u].push(sample(timer, u, opts, /*keep_min=*/ true)?);
    }
    // Columns 1..=12: under each stressor.
    for sc in &scenarios {
        let stress = launch(sc, opts);
        for (u, row) in times.iter_mut().enumerate() {
            let t = sample(timer, u, opts, /*keep_min=*/ false)?;
            // clamp: a measured interference column must never beat the
            // baseline (validate() enforces >= 0.98×; equality is fine)
            row.push(t.max(row[0]));
        }
        let work = stress.stop();
        crate::log_debug!(
            "scenario {} complete (stressor iterations: {work})",
            sc.label()
        );
    }

    Ok(TimingDb::new(
        timer.model_name(),
        (0..m).map(|u| timer.unit_name(u)).collect(),
        times,
        "measured",
    ))
}

fn launch(sc: &Scenario, opts: &MeasureOpts) -> Stressor {
    let cores = opts.stress_cores.clone().or_else(|| {
        // default placement: the first 8 cores (EP 0), mirroring the
        // paper's single-real-EP methodology
        Some(affinity::ep_cores(0, 8.min(affinity::num_cpus())))
    });
    Stressor::launch(*sc, cores)
}

fn sample(
    timer: &mut dyn UnitTimer,
    u: usize,
    opts: &MeasureOpts,
    keep_min: bool,
) -> Result<f64> {
    for _ in 0..opts.warmup {
        timer.time_unit(u)?;
    }
    let mut xs = Vec::with_capacity(opts.reps);
    for _ in 0..opts.reps.max(1) {
        xs.push(timer.time_unit(u)?);
    }
    xs.sort_by(f64::total_cmp);
    Ok(if keep_min { xs[0] } else { xs[xs.len() / 2] })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Fake timer with a programmable slowdown responding to live
    /// stressors — enough to exercise the measurement protocol.
    struct FakeTimer {
        calls: usize,
    }

    impl UnitTimer for FakeTimer {
        fn num_units(&self) -> usize {
            3
        }
        fn unit_name(&self, u: usize) -> String {
            format!("u{u}")
        }
        fn model_name(&self) -> String {
            "fake".into()
        }
        fn time_unit(&mut self, u: usize) -> Result<f64> {
            self.calls += 1;
            // deterministic base per unit + tiny call-dependent wobble
            Ok(1e-3 * (u + 1) as f64 + 1e-7 * (self.calls % 3) as f64)
        }
    }

    #[test]
    fn measure_produces_valid_db() {
        let mut t = FakeTimer { calls: 0 };
        let opts = MeasureOpts { reps: 3, warmup: 1, stress_cores: Some(vec![0]) };
        let db = measure(&mut t, &opts).unwrap();
        db.validate().unwrap();
        assert_eq!(db.num_units(), 3);
        assert_eq!(db.source, "measured");
        assert_eq!(db.unit_names, vec!["u0", "u1", "u2"]);
    }

    #[test]
    fn interference_columns_clamped_to_baseline() {
        let mut t = FakeTimer { calls: 0 };
        let opts = MeasureOpts { reps: 3, warmup: 0, stress_cores: Some(vec![0]) };
        let db = measure(&mut t, &opts).unwrap();
        for u in 0..db.num_units() {
            for s in 1..=db.num_scenarios() {
                assert!(db.time(u, s) >= db.base_time(u));
            }
        }
    }
}
