//! The per-layer timing database — the paper's §3.3 "Database Creation".
//!
//! The paper measures each of the m network layers alone and under n
//! interference scenarios on one real execution place, stores the
//! m×(n+1) matrix, and drives all simulation from lookups in it. We do
//! the same, from two sources:
//!
//! * [`synth`] — a calibrated synthetic database derived from unit FLOPs /
//!   byte volumes and the Table-1 scenario pressures (deterministic; the
//!   default for experiments).
//! * [`measure`] — real measurements of the AOT-compiled HLO units through
//!   the PJRT runtime, alone and with [`crate::interference::Stressor`]s
//!   running (`odin bench-db`; host-dependent).

pub mod measure;
pub mod synth;

use crate::interference::NUM_SCENARIOS;
use crate::json::{parse, Value};

/// The m×(n+1) matrix: `times[unit][scenario]`, seconds per query;
/// scenario 0 = interference-free.
#[derive(Clone, Debug, PartialEq)]
pub struct TimingDb {
    pub model: String,
    pub unit_names: Vec<String>,
    pub times: Vec<Vec<f64>>,
    /// Where the numbers came from ("synthetic" | "measured").
    pub source: String,
}

impl TimingDb {
    pub fn new(
        model: impl Into<String>,
        unit_names: Vec<String>,
        times: Vec<Vec<f64>>,
        source: impl Into<String>,
    ) -> TimingDb {
        let db = TimingDb {
            model: model.into(),
            unit_names,
            times,
            source: source.into(),
        };
        db.validate().expect("invalid TimingDb");
        db
    }

    pub fn num_units(&self) -> usize {
        self.times.len()
    }

    pub fn num_scenarios(&self) -> usize {
        NUM_SCENARIOS
    }

    /// Execution time of `unit` under `scenario` (0 = none). This is THE
    /// hot lookup of the whole simulator; callers index directly.
    #[inline]
    pub fn time(&self, unit: usize, scenario: usize) -> f64 {
        self.times[unit][scenario]
    }

    /// Interference-free time of a unit.
    #[inline]
    pub fn base_time(&self, unit: usize) -> f64 {
        self.times[unit][0]
    }

    /// Sum of interference-free unit times (serial latency floor).
    pub fn total_base_time(&self) -> f64 {
        (0..self.num_units()).map(|u| self.base_time(u)).sum()
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.times.len() != self.unit_names.len() {
            return Err(format!(
                "{} rows vs {} names",
                self.times.len(),
                self.unit_names.len()
            ));
        }
        if self.times.is_empty() {
            return Err("empty database".into());
        }
        for (u, row) in self.times.iter().enumerate() {
            if row.len() != NUM_SCENARIOS + 1 {
                return Err(format!(
                    "unit {u}: {} columns, want {}",
                    row.len(),
                    NUM_SCENARIOS + 1
                ));
            }
            for (s, &t) in row.iter().enumerate() {
                if !(t.is_finite() && t > 0.0) {
                    return Err(format!("unit {u} scenario {s}: bad time {t}"));
                }
            }
            for s in 1..row.len() {
                if row[s] < row[0] * 0.98 {
                    return Err(format!(
                        "unit {u} scenario {s}: interference faster than \
                         baseline ({} < {})",
                        row[s], row[0]
                    ));
                }
            }
        }
        Ok(())
    }

    /// Worst-case slowdown any scenario inflicts on any unit (Fig 4 max).
    pub fn max_slowdown(&self) -> f64 {
        self.times
            .iter()
            .flat_map(|row| row[1..].iter().map(move |&t| t / row[0]))
            .fold(1.0, f64::max)
    }

    // -- persistence --------------------------------------------------

    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("model", Value::from(self.model.clone())),
            ("source", Value::from(self.source.clone())),
            (
                "unit_names",
                Value::arr(
                    self.unit_names.iter().map(|n| Value::from(n.clone())).collect(),
                ),
            ),
            (
                "times",
                Value::arr(
                    self.times
                        .iter()
                        .map(|row| {
                            Value::arr(row.iter().map(|&t| Value::from(t)).collect())
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(v: &Value) -> Result<TimingDb, String> {
        let model = v.get("model").as_str().ok_or("missing model")?.to_string();
        let source = v
            .get("source")
            .as_str()
            .unwrap_or("unknown")
            .to_string();
        let unit_names = v
            .get("unit_names")
            .as_arr()
            .ok_or("missing unit_names")?
            .iter()
            .map(|n| n.as_str().map(String::from).ok_or("bad unit name"))
            .collect::<Result<Vec<_>, _>>()?;
        let times = v
            .get("times")
            .as_arr()
            .ok_or("missing times")?
            .iter()
            .map(|row| row.as_f64_vec().ok_or("bad times row"))
            .collect::<Result<Vec<_>, _>>()?;
        let db = TimingDb { model, unit_names, times, source };
        db.validate()?;
        Ok(db)
    }

    pub fn save(&self, path: &str) -> std::io::Result<()> {
        crate::json::write_file(path, &self.to_json())
    }

    pub fn load(path: &str) -> Result<TimingDb, String> {
        let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
        let v = parse(&text).map_err(|e| e.to_string())?;
        TimingDb::from_json(&v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;

    fn tiny_db() -> TimingDb {
        synth::synthesize(&models::vgg16(32), 7)
    }

    #[test]
    fn validate_catches_shape_errors() {
        let mut db = tiny_db();
        db.times[3].pop();
        assert!(db.validate().is_err());
    }

    #[test]
    fn validate_catches_negative_times() {
        let mut db = tiny_db();
        db.times[0][0] = -1.0;
        assert!(db.validate().is_err());
    }

    #[test]
    fn validate_catches_fast_interference() {
        let mut db = tiny_db();
        db.times[0][3] = db.times[0][0] * 0.5;
        assert!(db.validate().is_err());
    }

    #[test]
    fn json_roundtrip() {
        let db = tiny_db();
        let back = TimingDb::from_json(&db.to_json()).unwrap();
        assert_eq!(db, back);
    }

    #[test]
    fn file_roundtrip() {
        let db = tiny_db();
        let path = std::env::temp_dir().join("odin_db_test.json");
        let path = path.to_str().unwrap();
        db.save(path).unwrap();
        let back = TimingDb::load(path).unwrap();
        assert_eq!(db, back);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn max_slowdown_above_one() {
        assert!(tiny_db().max_slowdown() > 1.0);
    }

    #[test]
    fn base_lookup_is_column_zero() {
        let db = tiny_db();
        assert_eq!(db.base_time(2), db.time(2, 0));
    }
}
