//! AOT artifact manifest: the contract between `python/compile/aot.py`
//! and the rust runtime.
//!
//! `artifacts/manifest.json` indexes, per model, one HLO-text module per
//! schedulable unit plus optional gold tensors. This module parses and
//! validates it (shapes chain, files exist) and cross-checks the unit
//! structure against the rust-side [`crate::models`] metadata.

use std::path::{Path, PathBuf};

use crate::bail;
use crate::json::{parse, Value};
use crate::models::UnitKind;
use crate::util::error::{Context as _, Result};

#[derive(Clone, Debug)]
pub struct GoldFiles {
    pub input: PathBuf,
    pub output: PathBuf,
    pub params: Vec<PathBuf>,
}

#[derive(Clone, Debug)]
pub struct UnitArtifact {
    pub index: usize,
    pub name: String,
    pub kind: UnitKind,
    pub hlo_path: PathBuf,
    pub in_shape: Vec<usize>,
    pub out_shape: Vec<usize>,
    pub param_shapes: Vec<Vec<usize>>,
    pub flops: u64,
    pub gold: Option<GoldFiles>,
}

#[derive(Clone, Debug)]
pub struct ModelArtifacts {
    pub name: String,
    pub input_shape: Vec<usize>,
    pub seed: u64,
    pub units: Vec<UnitArtifact>,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub root: PathBuf,
    pub spatial: usize,
    pub batch: usize,
    pub models: Vec<ModelArtifacts>,
}

impl Manifest {
    /// Load and validate `<root>/manifest.json`.
    pub fn load(root: impl AsRef<Path>) -> Result<Manifest> {
        let root = root.as_ref().to_path_buf();
        let mpath = root.join("manifest.json");
        let text = std::fs::read_to_string(&mpath)
            .with_context(|| format!("reading {} (run `make artifacts`)", mpath.display()))?;
        let v = parse(&text)?;
        if v.get("format").as_usize() != Some(1) {
            bail!("unsupported manifest format {:?}", v.get("format"));
        }
        let spatial = v.get("spatial").as_usize().context("spatial")?;
        let batch = v.get("batch").as_usize().context("batch")?;
        let mut models = Vec::new();
        for (name, mv) in v.get("models").as_obj().context("models")? {
            models.push(parse_model(&root, name, mv)?);
        }
        models.sort_by(|a, b| a.name.cmp(&b.name));
        let m = Manifest { root, spatial, batch, models };
        m.validate()?;
        Ok(m)
    }

    pub fn model(&self, name: &str) -> Option<&ModelArtifacts> {
        self.models.iter().find(|m| m.name == name)
    }

    fn validate(&self) -> Result<()> {
        for m in &self.models {
            if m.units.is_empty() {
                bail!("{}: no units", m.name);
            }
            for u in &m.units {
                if !u.hlo_path.exists() {
                    bail!("{}/{}: missing {}", m.name, u.name, u.hlo_path.display());
                }
            }
            // shapes must chain (element count preserved across the
            // flatten boundary)
            for w in m.units.windows(2) {
                let out: usize = w[0].out_shape.iter().product();
                let inp: usize = w[1].in_shape.iter().product();
                if out != inp {
                    bail!(
                        "{}: {} -> {} shape break ({out} vs {inp})",
                        m.name,
                        w[0].name,
                        w[1].name
                    );
                }
            }
            // cross-check against the rust model metadata when available
            if let Some(spec) = crate::models::build(&m.name, self.spatial) {
                if spec.num_units() != m.units.len() {
                    bail!(
                        "{}: manifest has {} units, models:: says {}",
                        m.name,
                        m.units.len(),
                        spec.num_units()
                    );
                }
            }
        }
        Ok(())
    }
}

fn parse_model(root: &Path, name: &str, v: &Value) -> Result<ModelArtifacts> {
    let units_v = v.get("units").as_arr().context("units")?;
    let mut units = Vec::with_capacity(units_v.len());
    for uv in units_v {
        let gold = if uv.get("gold").is_null() {
            None
        } else {
            let g = uv.get("gold");
            Some(GoldFiles {
                input: root.join(g.get("input").as_str().context("gold.input")?),
                output: root.join(g.get("output").as_str().context("gold.output")?),
                params: g
                    .get("params")
                    .as_arr()
                    .context("gold.params")?
                    .iter()
                    .map(|p| Ok(root.join(p.as_str().context("gold param")?)))
                    .collect::<Result<Vec<_>>>()?,
            })
        };
        let kind_s = uv.get("kind").as_str().context("kind")?;
        units.push(UnitArtifact {
            index: uv.get("index").as_usize().context("index")?,
            name: uv.get("name").as_str().context("name")?.to_string(),
            kind: UnitKind::parse(kind_s)
                .with_context(|| format!("unknown kind {kind_s}"))?,
            hlo_path: root.join(uv.get("hlo").as_str().context("hlo")?),
            in_shape: uv.get("in_shape").as_usize_vec().context("in_shape")?,
            out_shape: uv.get("out_shape").as_usize_vec().context("out_shape")?,
            param_shapes: uv
                .get("param_shapes")
                .as_arr()
                .context("param_shapes")?
                .iter()
                .map(|s| s.as_usize_vec().context("param shape"))
                .collect::<Result<Vec<_>>>()?,
            flops: uv.get("flops").as_u64().context("flops")?,
            gold,
        })
    }
    units.sort_by_key(|u| u.index);
    Ok(ModelArtifacts {
        name: name.to_string(),
        input_shape: v.get("input_shape").as_usize_vec().context("input_shape")?,
        seed: v.get("seed").as_u64().unwrap_or(0),
        units,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_root() -> Option<PathBuf> {
        let p = PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"));
        p.join("manifest.json").exists().then_some(p)
    }

    #[test]
    fn loads_real_manifest() {
        let Some(root) = artifacts_root() else { return };
        let m = Manifest::load(&root).unwrap();
        assert!(m.model("vgg16").is_some());
        let vgg = m.model("vgg16").unwrap();
        assert_eq!(vgg.units.len(), 16);
        assert_eq!(vgg.units[0].name, "conv1_1");
        assert!(vgg.units[0].gold.is_some());
        assert_eq!(vgg.units[0].param_shapes.len(), 2);
    }

    #[test]
    fn resnet50_has_18_units() {
        let Some(root) = artifacts_root() else { return };
        let m = Manifest::load(&root).unwrap();
        assert_eq!(m.model("resnet50").unwrap().units.len(), 18);
    }

    #[test]
    fn missing_manifest_is_clear_error() {
        let err = Manifest::load("/nonexistent/dir").unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
