//! Stand-in for the external `xla` (PJRT) crate, which is not vendored in
//! this hermetic offline build.
//!
//! It preserves the exact API surface `runtime::{executor, tensor}`
//! program against — `PjRtClient::cpu()` → `HloModuleProto::from_text_file`
//! → `XlaComputation::from_proto` → `compile` → `execute` → `to_tuple1` —
//! so the real crate can be swapped back in by deleting this module and
//! its `use super::xla;` imports, without touching any call site. The
//! host-side [`Literal`] plumbing (shape + f32 payload) is implemented for
//! real; every entry point that would reach PJRT reports a clear error
//! instead, which surfaces as "backend unavailable" from `odin bench-db`,
//! `odin verify`, and `odin serve` (the artifact-free simulation and
//! experiment paths never get here).

use std::path::Path;

use crate::util::error::{OdinError, Result};

fn unavailable(what: &str) -> OdinError {
    OdinError::msg(format!(
        "{what}: the PJRT/XLA backend is not vendored in this hermetic build; \
         swap runtime::xla for the real `xla` crate to execute AOT artifacts"
    ))
}

/// Host tensor literal: f32 payload plus dimensions.
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal over a borrowed slice.
    pub fn vec1(xs: &[f32]) -> Literal {
        Literal { data: xs.to_vec(), dims: vec![xs.len() as i64] }
    }

    /// Reshape without changing the element count.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want < 0 || want as usize != self.data.len() {
            return Err(OdinError::msg(format!(
                "reshape {:?} -> {:?}: element count mismatch ({} elements)",
                self.dims,
                dims,
                self.data.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Copy the payload out (f32 only, matching the AOT artifact dtype).
    pub fn to_vec<T: From<f32>>(&self) -> Result<Vec<T>> {
        Ok(self.data.iter().map(|&x| T::from(x)).collect())
    }

    /// AOT lowers with `return_tuple=True`; the stub's literals are
    /// already the single tuple element.
    pub fn to_tuple1(self) -> Result<Literal> {
        Ok(self)
    }
}

/// PJRT client handle (stub: construction reports the missing backend).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto> {
        let _ = path.as_ref();
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// XLA computation wrapper (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer returned by `execute` (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_reshape_roundtrip() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let r = l.reshape(&[2, 3]).unwrap();
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(l.reshape(&[4, 2]).is_err());
    }

    #[test]
    fn backend_entry_points_report_unavailable() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(format!("{e}").contains("not vendored"), "{e}");
        assert!(HloModuleProto::from_text_file("/tmp/nope.hlo").is_err());
    }
}
