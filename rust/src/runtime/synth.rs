//! Synthetic in-thread execution backend for the live serving path.
//!
//! The PJRT backend is stubbed in this hermetic build (`runtime::xla`),
//! but the live harness needs stage workers that burn *real, measurable
//! CPU time on their own pinned cores* — that is what co-located
//! stressors degrade and what the monitor detects. This backend gives
//! each model unit a busy-work budget proportional to its FLOP count
//! (calibrated against the host once) and executes unit ranges inline on
//! the **calling** thread, so a stage worker pinned to EP k's cores does
//! its compute exactly where an interference generator pinned to the same
//! cores will contend with it.
//!
//! Tensors pass through unchanged — the synthetic path models *time*, not
//! numerics (the PJRT path owns numerics; `odin verify` covers it).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

use crate::bail;
use crate::models::ModelSpec;
use crate::util::error::Result;

use super::tensor::Tensor;

/// One timed calibration probe must run at least this long for a stable
/// iterations/second estimate.
const CALIBRATE_SECS: f64 = 2e-3;

/// Dependent ALU chain, `iters` iterations — the same loop body as the
/// CPU stressor, so victim and aggressor contend for identical resources.
fn busy(iters: u64) -> u64 {
    let mut x: u64 = 0x9E3779B97F4A7C15;
    let mut f: f64 = 1.000000001;
    for _ in 0..iters {
        x = x
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        f = (f * 1.0000001).sqrt() + 0.5;
    }
    std::hint::black_box(f);
    x
}

/// Host ALU-loop rate (iterations/second), measured once per process.
fn alu_rate() -> f64 {
    static RATE: OnceLock<f64> = OnceLock::new();
    *RATE.get_or_init(|| {
        let mut iters = 50_000u64;
        loop {
            let t0 = Instant::now();
            std::hint::black_box(busy(iters));
            let dt = t0.elapsed().as_secs_f64();
            if dt > CALIBRATE_SECS || iters >= 1 << 30 {
                return iters as f64 / dt.max(1e-9);
            }
            iters *= 4;
        }
    })
}

/// A calibrated synthetic executor: per-unit busy-work budgets sized so
/// one full-model query costs roughly `query_ms` milliseconds on an idle
/// host, split across units proportionally to their FLOPs.
pub struct SynthBackend {
    model: String,
    spatial: usize,
    iters: Vec<u64>,
    /// Busy-work multiplier of the *active model variant* (f64 bits):
    /// the degrade ladder drops it to the thin variant's FLOP ratio and
    /// restores it on upgrade, without rebuilding the backend the stage
    /// workers already share. Exactly 1.0 by default — multiplying every
    /// budget by 1.0 reproduces the historical iteration counts bit for
    /// bit.
    scale: AtomicU64,
}

impl SynthBackend {
    pub fn new(spec: &ModelSpec, query_ms: f64) -> SynthBackend {
        assert!(query_ms > 0.0, "query_ms must be positive");
        let total_flops: u128 =
            spec.units.iter().map(|u| u.flops as u128).sum::<u128>().max(1);
        let total_iters = (alu_rate() * query_ms / 1e3).max(1.0) as u128;
        let iters = spec
            .units
            .iter()
            .map(|u| {
                ((total_iters * u.flops as u128 / total_flops) as u64).max(1)
            })
            .collect();
        SynthBackend {
            model: spec.name.clone(),
            spatial: spec.spatial,
            iters,
            scale: AtomicU64::new(1.0f64.to_bits()),
        }
    }

    /// Scale every unit's busy-work budget (degrade ladder: the thin
    /// variant's FLOP ratio on the way down, 1.0 on the way back up).
    pub fn set_work_scale(&self, scale: f64) {
        assert!(
            scale.is_finite() && scale > 0.0,
            "work scale must be positive and finite, got {scale}"
        );
        self.scale.store(scale.to_bits(), Ordering::Relaxed);
    }

    /// The active busy-work multiplier (1.0 = the full model).
    pub fn work_scale(&self) -> f64 {
        f64::from_bits(self.scale.load(Ordering::Relaxed))
    }

    pub fn model_name(&self) -> &str {
        &self.model
    }

    pub fn num_units(&self) -> usize {
        self.iters.len()
    }

    /// A plausible query shape for this model (NHWC, batch 1). The
    /// synthetic path only threads tensors through; any shape serves.
    pub fn input_shape(&self) -> Vec<usize> {
        vec![1, self.spatial, self.spatial, 3]
    }

    /// Execute units `[start, end)` inline on the calling thread,
    /// returning the (pass-through) activation and the measured seconds.
    pub fn run_range(
        &self,
        start: usize,
        end: usize,
        input: Tensor,
    ) -> Result<(Tensor, f64)> {
        self.run_range_batched(start, end, input, 1)
    }

    /// Batched variant: each unit's busy-work scales by the
    /// FLOP-sublinear `batch_factor(batch)` from `pipeline::cost`, so a
    /// `b`-query batch burns genuinely more (but sublinearly more) CPU
    /// on the worker's pinned cores. `batch == 1` is the exact
    /// historical path (`factor == 1.0` ⇒ identical iteration counts).
    pub fn run_range_batched(
        &self,
        start: usize,
        end: usize,
        input: Tensor,
        batch: usize,
    ) -> Result<(Tensor, f64)> {
        if start >= end || end > self.iters.len() {
            bail!(
                "{}: bad unit range {start}..{end} ({} units)",
                self.model,
                self.iters.len()
            );
        }
        let factor =
            crate::pipeline::batch_factor(batch) * self.work_scale();
        let t0 = Instant::now();
        for &n in &self.iters[start..end] {
            std::hint::black_box(busy((n as f64 * factor) as u64));
        }
        Ok((input, t0.elapsed().as_secs_f64()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;

    fn backend() -> SynthBackend {
        SynthBackend::new(&models::vgg16(8), 1.0)
    }

    #[test]
    fn work_proportional_to_flops() {
        let spec = models::vgg16(8);
        let b = SynthBackend::new(&spec, 2.0);
        assert_eq!(b.num_units(), spec.num_units());
        // the heaviest unit gets the largest budget
        let heaviest = spec
            .units
            .iter()
            .enumerate()
            .max_by_key(|(_, u)| u.flops)
            .unwrap()
            .0;
        let max_iters = *b.iters.iter().max().unwrap();
        assert_eq!(b.iters[heaviest], max_iters);
        // total budget roughly matches the calibrated 2 ms target
        let total: u64 = b.iters.iter().sum();
        assert!(total >= b.num_units() as u64);
    }

    #[test]
    fn run_range_times_positive_and_passthrough() {
        let b = backend();
        let x = Tensor::random(&b.input_shape(), 1, 1.0);
        let want = x.data.clone();
        let (out, dt) = b.run_range(0, b.num_units(), x).unwrap();
        assert!(dt > 0.0);
        assert_eq!(out.data, want);
    }

    #[test]
    fn batched_run_burns_more_time_sublinearly() {
        let b = backend();
        let x = || Tensor::random(&b.input_shape(), 1, 1.0);
        let time = |batch: usize| {
            // median of 3 to damp scheduler noise
            let mut ts: Vec<f64> = (0..3)
                .map(|_| {
                    b.run_range_batched(0, b.num_units(), x(), batch)
                        .unwrap()
                        .1
                })
                .collect();
            ts.sort_by(f64::total_cmp);
            ts[1]
        };
        let t1 = time(1);
        let t8 = time(8);
        // factor(8) = 2.75: the batched traversal costs more than one
        // query but far less than eight
        assert!(t8 > t1 * 1.5, "t1={t1} t8={t8}");
        assert!(t8 < t1 * 8.0, "t1={t1} t8={t8}");
    }

    #[test]
    fn work_scale_defaults_to_identity_and_cuts_busy_time() {
        let b = backend();
        assert_eq!(b.work_scale(), 1.0);
        let x = || Tensor::random(&b.input_shape(), 1, 1.0);
        let time = |scale: f64| {
            b.set_work_scale(scale);
            let mut ts: Vec<f64> = (0..3)
                .map(|_| b.run_range(0, b.num_units(), x()).unwrap().1)
                .collect();
            ts.sort_by(f64::total_cmp);
            ts[1]
        };
        let full = time(1.0);
        let thin = time(0.25);
        assert!(thin < full * 0.8, "full={full} thin={thin}");
        b.set_work_scale(1.0);
        assert_eq!(b.work_scale(), 1.0);
    }

    #[test]
    fn bad_ranges_error() {
        let b = backend();
        let x = || Tensor::zeros(&[1]);
        assert!(b.run_range(3, 3, x()).is_err());
        assert!(b.run_range(5, 2, x()).is_err());
        assert!(b.run_range(0, b.num_units() + 1, x()).is_err());
    }
}
