//! The PJRT model runtime: compile each unit's HLO text once, then execute
//! units / unit-ranges from the serving hot path.
//!
//! Pattern from /opt/xla-example/load_hlo.rs: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute` → `to_tuple1` (AOT lowers with
//! return_tuple=True).
//!
//! NOT Send (PjRtClient is Rc-based): multi-threaded callers go through
//! [`super::service::ExecService`].
//!
//! The `xla` crate is not vendored in this hermetic build; the call sites
//! below compile against [`super::xla`], a same-API stub whose backend
//! entry points report "unavailable" (see that module for the swap-back
//! recipe).

use std::time::Instant;

use crate::bail;
use crate::database::measure::UnitTimer;
use crate::util::error::{Context as _, Result};

use super::artifact::{ModelArtifacts, UnitArtifact};
use super::tensor::Tensor;
use super::xla;

struct CompiledUnit {
    exe: xla::PjRtLoadedExecutable,
    /// Parameter literals, kept device-ready so the hot path only uploads
    /// the activation (weights don't change between queries).
    params: Vec<xla::Literal>,
}

pub struct ModelRuntime {
    client: xla::PjRtClient,
    model: ModelArtifacts,
    units: Vec<CompiledUnit>,
}

impl ModelRuntime {
    /// Compile every unit of `model`. Parameters are loaded from gold
    /// files where present, otherwise generated deterministically from
    /// the manifest seed.
    pub fn load(model: &ModelArtifacts) -> Result<ModelRuntime> {
        let client = xla::PjRtClient::cpu()?;
        let mut units = Vec::with_capacity(model.units.len());
        for u in &model.units {
            units.push(compile_unit(&client, model, u)?);
        }
        Ok(ModelRuntime { client, model: model.clone(), units })
    }

    pub fn model(&self) -> &ModelArtifacts {
        &self.model
    }

    pub fn num_units(&self) -> usize {
        self.units.len()
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Execute one unit on `input`, returning its output tensor.
    pub fn run_unit(&self, u: usize, input: &Tensor) -> Result<Tensor> {
        let spec = &self.model.units[u];
        let cu = &self.units[u];
        // reshape flat/NHWC inputs as the unit expects (dense units take
        // the flattened activation of a conv unit)
        let want: usize = spec.in_shape.iter().product();
        if input.len() != want {
            bail!(
                "{}/{}: input has {} elements, unit wants {want}",
                self.model.name,
                spec.name,
                input.len()
            );
        }
        let x = Tensor::new(spec.in_shape.clone(), input.data.clone())?
            .to_literal()?;
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(1 + cu.params.len());
        args.push(&x);
        args.extend(cu.params.iter());
        let result = cu.exe.execute::<&xla::Literal>(&args)?[0][0]
            .to_literal_sync()?;
        let out = result.to_tuple1()?;
        Tensor::from_literal(&out, spec.out_shape.clone())
    }

    /// Execute a contiguous unit range `[start, end)` (= one pipeline
    /// stage), chaining activations.
    pub fn run_range(&self, start: usize, end: usize, input: &Tensor) -> Result<Tensor> {
        if start >= end || end > self.units.len() {
            bail!("bad unit range {start}..{end}");
        }
        let mut act = self.run_unit(start, input)?;
        for u in start + 1..end {
            act = self.run_unit(u, &act)?;
        }
        Ok(act)
    }

    /// A deterministic model input (for probes/benches).
    pub fn example_input(&self) -> Tensor {
        Tensor::random(&self.model.input_shape, 0x1A7, 1.0)
    }

    /// Verify every unit that has gold tensors: run it on the gold input
    /// with the gold params and compare. Returns (checked, max_abs_diff).
    pub fn verify_gold(&self, tol: f64) -> Result<(usize, f64)> {
        let mut checked = 0;
        let mut worst = 0.0f64;
        for (u, spec) in self.model.units.iter().enumerate() {
            let Some(gold) = &spec.gold else { continue };
            let input = Tensor::from_bin_file(
                gold.input.to_str().unwrap(),
                &spec.in_shape,
            )?;
            // gold params override the generated ones for this run
            let params: Vec<xla::Literal> = gold
                .params
                .iter()
                .zip(&spec.param_shapes)
                .map(|(p, s)| {
                    Tensor::from_bin_file(p.to_str().unwrap(), s)?.to_literal()
                })
                .collect::<Result<Vec<_>>>()?;
            let x = input.to_literal()?;
            let mut args: Vec<&xla::Literal> = vec![&x];
            args.extend(params.iter());
            let result = self.units[u].exe.execute::<&xla::Literal>(&args)?[0][0]
                .to_literal_sync()?;
            let out = Tensor::from_literal(
                &result.to_tuple1()?,
                spec.out_shape.clone(),
            )?;
            let want = Tensor::from_bin_file(
                gold.output.to_str().unwrap(),
                &spec.out_shape,
            )?;
            let diff = out.max_abs_diff(&want);
            worst = worst.max(diff);
            if diff > tol {
                bail!(
                    "{}/{}: gold mismatch, max |Δ| = {diff:e} > {tol:e}",
                    self.model.name,
                    spec.name
                );
            }
            checked += 1;
        }
        Ok((checked, worst))
    }
}

fn compile_unit(
    client: &xla::PjRtClient,
    model: &ModelArtifacts,
    u: &UnitArtifact,
) -> Result<CompiledUnit> {
    let proto = xla::HloModuleProto::from_text_file(&u.hlo_path)
        .with_context(|| format!("parsing {}", u.hlo_path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    let exe = client
        .compile(&comp)
        .with_context(|| format!("compiling {}/{}", model.name, u.name))?;
    // deterministic params: unique seed per (model seed, unit, param)
    let params = u
        .param_shapes
        .iter()
        .enumerate()
        .map(|(pi, shape)| {
            let seed = model.seed ^ ((u.index as u64) << 16) ^ ((pi as u64) << 40) ^ 0x9E37;
            let scale = (2.0 / shape.iter().product::<usize>() as f32).sqrt();
            Tensor::random(shape, seed, scale).to_literal()
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(CompiledUnit { exe, params })
}

/// `odin bench-db` measurement adapter.
pub struct RuntimeTimer<'a> {
    pub rt: &'a ModelRuntime,
    inputs: Vec<Tensor>,
}

impl<'a> RuntimeTimer<'a> {
    /// Precompute each unit's input by chaining the example input through
    /// the model once (so per-unit timing excludes upstream compute).
    pub fn new(rt: &'a ModelRuntime) -> Result<RuntimeTimer<'a>> {
        let mut inputs = Vec::with_capacity(rt.num_units());
        let mut act = rt.example_input();
        for u in 0..rt.num_units() {
            inputs.push(act.clone());
            act = rt.run_unit(u, &act)?;
        }
        Ok(RuntimeTimer { rt, inputs })
    }
}

impl UnitTimer for RuntimeTimer<'_> {
    fn num_units(&self) -> usize {
        self.rt.num_units()
    }

    fn unit_name(&self, u: usize) -> String {
        self.rt.model.units[u].name.clone()
    }

    fn model_name(&self) -> String {
        self.rt.model.name.clone()
    }

    fn time_unit(&mut self, u: usize) -> Result<f64> {
        let t0 = Instant::now();
        let out = self.rt.run_unit(u, &self.inputs[u])?;
        std::hint::black_box(&out.data[0]);
        Ok(t0.elapsed().as_secs_f64())
    }
}
