//! Execution service: a dedicated thread owning the (non-Send, Rc-based)
//! PJRT runtime, serving unit-range execution requests from the pipeline
//! stage workers over channels.
//!
//! Stage workers each get a cloneable [`ExecHandle`]; calls block until
//! the service thread replies. On the paper's multi-EP hardware each EP
//! would own its own service (one PJRT client per EP); on this sandbox a
//! single service models the shared substrate while preserving the exact
//! bind-to-stage message flow.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use crate::err;
use crate::util::error::Result;

use super::artifact::ModelArtifacts;
use super::executor::ModelRuntime;
use super::synth::SynthBackend;
use super::tensor::Tensor;

enum Request {
    /// Execute units [start, end) on input; reply with (output, seconds).
    RunRange {
        start: usize,
        end: usize,
        input: Tensor,
        reply: Sender<Result<(Tensor, f64)>>,
    },
    Shutdown,
}

/// Cloneable handle used by stage workers.
#[derive(Clone)]
pub struct ExecHandle {
    inner: HandleInner,
}

#[derive(Clone)]
enum HandleInner {
    /// Requests funnel to the dedicated PJRT service thread.
    Service(Sender<Request>),
    /// Calibrated busy-work executed inline on the *calling* thread — the
    /// stage worker's own pinned cores do the compute, so co-located
    /// stressors genuinely contend with it (see [`SynthBackend`]).
    Synth(Arc<SynthBackend>),
}

// Sender is Send; the handle carries no XLA state.
impl ExecHandle {
    /// A handle over the synthetic in-thread backend (no PJRT needed).
    pub fn synthetic(backend: SynthBackend) -> ExecHandle {
        ExecHandle { inner: HandleInner::Synth(Arc::new(backend)) }
    }

    /// Execute a unit range. Service-backed handles block until the
    /// service thread replies; synthetic handles compute inline.
    pub fn run_range(&self, start: usize, end: usize, input: Tensor) -> Result<(Tensor, f64)> {
        match &self.inner {
            HandleInner::Service(tx) => {
                let (reply, rx) = channel();
                tx.send(Request::RunRange { start, end, input, reply })
                    .map_err(|_| err!("exec service gone"))?;
                rx.recv().map_err(|_| err!("exec service dropped reply"))?
            }
            HandleInner::Synth(b) => b.run_range(start, end, input),
        }
    }

    /// Scale the synthetic backend's busy-work budgets — the degrade
    /// ladder's variant switch (thin FLOP ratio down, 1.0 back up).
    /// Errors on the PJRT service path, which compiles one model and
    /// has no variant to switch to.
    pub fn set_work_scale(&self, scale: f64) -> Result<()> {
        match &self.inner {
            HandleInner::Synth(b) => {
                b.set_work_scale(scale);
                Ok(())
            }
            HandleInner::Service(_) => Err(err!(
                "work-scale switching requires the synthetic backend; \
                 the PJRT service serves one compiled model"
            )),
        }
    }

    /// The synthetic backend's active busy-work multiplier (`None` on
    /// the PJRT service path).
    pub fn work_scale(&self) -> Option<f64> {
        match &self.inner {
            HandleInner::Synth(b) => Some(b.work_scale()),
            HandleInner::Service(_) => None,
        }
    }

    /// Execute a unit range for a `batch`-query batch. Only the
    /// synthetic backend executes batched (scaling its busy-work by the
    /// sublinear cost factor); the PJRT service path has no batched
    /// kernel, so it accepts `batch == 1` only — the CLI flag audits
    /// keep `--batch` off the artifact mode, this is the backstop.
    pub fn run_range_batched(
        &self,
        start: usize,
        end: usize,
        input: Tensor,
        batch: usize,
    ) -> Result<(Tensor, f64)> {
        match &self.inner {
            HandleInner::Synth(b) => b.run_range_batched(start, end, input, batch),
            HandleInner::Service(_) if batch <= 1 => {
                self.run_range(start, end, input)
            }
            HandleInner::Service(_) => Err(err!(
                "batched execution (batch={batch}) requires the \
                 synthetic backend; the PJRT service runs one query \
                 at a time"
            )),
        }
    }
}

/// The service thread wrapper.
pub struct ExecService {
    tx: Sender<Request>,
    thread: Option<JoinHandle<()>>,
}

impl ExecService {
    /// Spawn the service; compiles the model on the service thread (the
    /// client must be created where it is used).
    pub fn spawn(model: ModelArtifacts) -> Result<ExecService> {
        let (tx, rx): (Sender<Request>, Receiver<Request>) = channel();
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let thread = std::thread::Builder::new()
            .name("odin-exec".into())
            .spawn(move || {
                let rt = match ModelRuntime::load(&model) {
                    Ok(rt) => {
                        let _ = ready_tx.send(Ok(()));
                        rt
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                serve(rt, rx);
            })?;
        ready_rx
            .recv()
            .map_err(|_| err!("exec service died during load"))??;
        Ok(ExecService { tx, thread: Some(thread) })
    }

    pub fn handle(&self) -> ExecHandle {
        ExecHandle { inner: HandleInner::Service(self.tx.clone()) }
    }
}

impl Drop for ExecService {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

fn serve(rt: ModelRuntime, rx: Receiver<Request>) {
    while let Ok(req) = rx.recv() {
        match req {
            Request::Shutdown => break,
            Request::RunRange { start, end, input, reply } => {
                let t0 = Instant::now();
                let out = rt.run_range(start, end, &input);
                let dt = t0.elapsed().as_secs_f64();
                let _ = reply.send(out.map(|t| (t, dt)));
            }
        }
    }
}
