//! The AOT artifact runtime: load HLO-text units compiled by
//! `python/compile/aot.py` and execute them via PJRT from the serving hot
//! path. Python never runs here — the artifacts are self-contained.

pub mod artifact;
pub mod executor;
pub mod service;
pub mod synth;
pub mod tensor;
pub mod xla;

pub use artifact::{Manifest, ModelArtifacts, UnitArtifact};
pub use executor::{ModelRuntime, RuntimeTimer};
pub use service::{ExecHandle, ExecService};
pub use synth::SynthBackend;
pub use tensor::Tensor;
