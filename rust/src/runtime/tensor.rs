//! Host tensors: plain `Vec<f32>` + shape, the Send-able currency between
//! stage workers and the (single-threaded) XLA execution service.

use crate::bail;
use crate::util::error::{Context as _, Result};

use super::xla;

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {shape:?} wants {n} elements, got {}", data.len());
        }
        Ok(Tensor { shape, data })
    }

    pub fn zeros(shape: &[usize]) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    /// Deterministic pseudo-random fill in [-scale, scale] — the weight
    /// generator for performance runs (timing is value-independent; gold
    /// numerics use the AOT-dumped tensors instead).
    pub fn random(shape: &[usize], seed: u64, scale: f32) -> Tensor {
        let n: usize = shape.iter().product();
        let mut rng = crate::util::Rng::new(seed);
        let data = (0..n)
            .map(|_| (rng.f64() as f32 * 2.0 - 1.0) * scale)
            .collect();
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Load a raw little-endian f32 `.bin` (the AOT gold format).
    pub fn from_bin_file(path: &str, shape: &[usize]) -> Result<Tensor> {
        let bytes = std::fs::read(path).with_context(|| format!("reading {path}"))?;
        if bytes.len() % 4 != 0 {
            bail!("{path}: size {} not a multiple of 4", bytes.len());
        }
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Tensor::new(shape.to_vec(), data).with_context(|| path.to_string())
    }

    /// Convert to an XLA literal of this shape.
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        xla::Literal::vec1(&self.data).reshape(&dims)
    }

    /// Build from an XLA literal (f32 only).
    pub fn from_literal(lit: &xla::Literal, shape: Vec<usize>) -> Result<Tensor> {
        let data = lit.to_vec::<f32>()?;
        Tensor::new(shape, data)
    }

    /// Max absolute elementwise difference vs `other`.
    pub fn max_abs_diff(&self, other: &Tensor) -> f64 {
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs() as f64)
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_checks_shape() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn random_is_deterministic_and_bounded() {
        let a = Tensor::random(&[4, 4], 9, 0.5);
        let b = Tensor::random(&[4, 4], 9, 0.5);
        assert_eq!(a, b);
        assert!(a.data.iter().all(|x| x.abs() <= 0.5));
    }

    #[test]
    fn bin_roundtrip() {
        let t = Tensor::random(&[3, 5], 1, 1.0);
        let path = std::env::temp_dir().join("odin_tensor_test.bin");
        let bytes: Vec<u8> = t.data.iter().flat_map(|f| f.to_le_bytes()).collect();
        std::fs::write(&path, bytes).unwrap();
        let back = Tensor::from_bin_file(path.to_str().unwrap(), &[3, 5]).unwrap();
        assert_eq!(t, back);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn bin_file_shape_mismatch_rejected() {
        let path = std::env::temp_dir().join("odin_tensor_bad.bin");
        std::fs::write(&path, [0u8; 8]).unwrap();
        assert!(Tensor::from_bin_file(path.to_str().unwrap(), &[3]).is_err());
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn max_abs_diff_basic() {
        let a = Tensor::new(vec![3], vec![1.0, 2.0, 3.0]).unwrap();
        let b = Tensor::new(vec![3], vec![1.5, 2.0, 2.0]).unwrap();
        assert!((a.max_abs_diff(&b) - 1.0).abs() < 1e-12);
    }
}
