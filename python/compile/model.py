"""L2 JAX model definitions: CNN inference pipelines as schedulable units.

The paper schedules *network layers* onto pipeline stages. Here each model
is a list of `Unit`s — the indivisible things the rust coordinator may group
into stages:

  * VGG16        → 16 units (13 conv[+pool] + 3 dense), as in the paper.
  * ResNet-50    → 18 units (stem + 16 bottleneck blocks + classifier).
  * ResNet-152   → 52 units (stem + 50 bottleneck blocks + classifier),
                   matching the paper's "residual blocks as a single unit …
                   maximum number of pipeline stages is 52".

Each unit is a pure jax function `(x, *params) -> y` built on the L1 Pallas
kernels, lowered *separately* to HLO text by aot.py so the rust runtime can
execute any layer→stage grouping the rebalancer chooses.

Everything here is build-time only; nothing imports this at serving time.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from .kernels import conv2d, global_avgpool, linear, maxpool2d, scale_shift


@dataclasses.dataclass
class Unit:
    """One schedulable pipeline unit (a 'layer' in the paper's terms)."""

    name: str
    kind: str  # conv | conv_pool | dense | stem | block | classifier
    apply: Callable  # (x, *params) -> y
    param_shapes: list[tuple[int, ...]]
    in_shape: tuple[int, ...]
    out_shape: tuple[int, ...]
    flops: int  # MAC-based FLOP estimate, drives the synthetic database


@dataclasses.dataclass
class ModelDef:
    name: str
    input_shape: tuple[int, ...]
    units: list[Unit]

    @property
    def num_units(self) -> int:
        return len(self.units)

    def forward(self, x: jax.Array, params: Sequence[Sequence[jax.Array]]):
        """Full-model forward: chain every unit (pytest oracle for AOT)."""
        for unit, p in zip(self.units, params):
            x = unit.apply(x, *p)
        return x

    def init_params(self, seed: int = 0) -> list[list[jax.Array]]:
        """Deterministic He-style params for every unit.

        The same (seed, unit, index) derivation is documented in the AOT
        manifest so gold tensors are reproducible.
        """
        out = []
        for ui, unit in enumerate(self.units):
            key = jax.random.PRNGKey(seed * 7919 + ui)
            ps = []
            for pi, shape in enumerate(unit.param_shapes):
                k = jax.random.fold_in(key, pi)
                if len(shape) == 1:
                    # biases / BN shifts start at 0, BN scales at 1 — encode
                    # scale-vs-shift by parameter position (scale first).
                    ps.append(
                        jnp.ones(shape, jnp.float32)
                        if _is_scale(unit, pi)
                        else jnp.zeros(shape, jnp.float32)
                    )
                else:
                    fan_in = 1
                    for d in shape[:-1]:
                        fan_in *= d
                    std = (2.0 / fan_in) ** 0.5
                    ps.append(std * jax.random.normal(k, shape, jnp.float32))
            out.append(ps)
        return out


def _is_scale(unit: Unit, pi: int) -> bool:
    """BN scale params are the even-positioned 1-D params in BN-ful units."""
    if unit.kind not in ("stem", "block"):
        return False
    # param layout in BN units: (..., w, scale, shift, w, scale, shift, ...)
    # → a 1-D param directly following a >=2-D param is a scale.
    return pi > 0 and len(unit.param_shapes[pi]) == 1 and len(
        unit.param_shapes[pi - 1]
    ) > 1


# ---------------------------------------------------------------------------
# FLOP accounting (2 * MACs for convs/matmuls, elementwise ~1/elem)
# ---------------------------------------------------------------------------


def _conv_flops(out_shape, kh, kw, cin) -> int:
    n, h, w, cout = out_shape
    return 2 * n * h * w * cout * kh * kw * cin


def _dense_flops(m, k, n) -> int:
    return 2 * m * k * n


# ---------------------------------------------------------------------------
# VGG16
# ---------------------------------------------------------------------------

_VGG_PLAN = [
    # (name, cout, pool_after)
    ("conv1_1", 64, False),
    ("conv1_2", 64, True),
    ("conv2_1", 128, False),
    ("conv2_2", 128, True),
    ("conv3_1", 256, False),
    ("conv3_2", 256, False),
    ("conv3_3", 256, True),
    ("conv4_1", 512, False),
    ("conv4_2", 512, False),
    ("conv4_3", 512, True),
    ("conv5_1", 512, False),
    ("conv5_2", 512, False),
    ("conv5_3", 512, True),
]


def _conv_unit(pool: bool):
    if pool:
        def apply(x, w, b):
            return maxpool2d(conv2d(x, w, b, relu=True), k=2, stride=2)
    else:
        def apply(x, w, b):
            return conv2d(x, w, b, relu=True)
    return apply


def _dense_unit(relu: bool, flatten: bool):
    def apply(x, w, b):
        if flatten:
            x = x.reshape(x.shape[0], -1)
        return linear(x, w, b, relu=relu)
    return apply


def build_vgg16(
    spatial: int = 64, num_classes: int = 1000, batch: int = 1,
    fc_dim: int = 4096,
) -> ModelDef:
    """VGG16 as 16 schedulable units (the paper's 16 'layers').

    `spatial` scales the input resolution (paper: 224; default 64 for the
    single-core sandbox — see DESIGN.md substitutions). Pools fold into the
    preceding conv unit, as standard for pipeline scheduling.
    """
    if spatial % 32 != 0:
        raise ValueError(f"spatial must be a multiple of 32, got {spatial}")
    units: list[Unit] = []
    h = spatial
    cin = 3
    for name, cout, pool in _VGG_PLAN:
        in_shape = (batch, h, h, cin)
        out_h = h // 2 if pool else h
        out_shape = (batch, out_h, out_h, cout)
        units.append(
            Unit(
                name=name + ("_pool" if pool else ""),
                kind="conv_pool" if pool else "conv",
                apply=_conv_unit(pool),
                param_shapes=[(3, 3, cin, cout), (cout,)],
                in_shape=in_shape,
                out_shape=out_shape,
                flops=_conv_flops((batch, h, h, cout), 3, 3, cin),
            )
        )
        h, cin = out_h, cout
    flat = h * h * cin
    dense_plan = [
        ("fc1", flat, fc_dim, True, True),
        ("fc2", fc_dim, fc_dim, True, False),
        ("fc3", fc_dim, num_classes, False, False),
    ]
    for name, k, n, relu, flatten in dense_plan:
        units.append(
            Unit(
                name=name,
                kind="dense",
                apply=_dense_unit(relu, flatten),
                param_shapes=[(k, n), (n,)],
                in_shape=(batch, h, h, cin) if flatten else (batch, k),
                out_shape=(batch, n),
                flops=_dense_flops(batch, k, n),
            )
        )
    return ModelDef("vgg16", (batch, spatial, spatial, 3), units)


# ---------------------------------------------------------------------------
# ResNet-50 / ResNet-152 (bottleneck blocks as single units)
# ---------------------------------------------------------------------------


def _stem_apply(x, w, scale, shift):
    y = conv2d(x, w, stride=2, padding="SAME")
    y = scale_shift(y, scale, shift, relu=True)
    return maxpool2d(y, k=2, stride=2)


def _block_apply_proj(x, w1, s1, b1, w2, s2, b2, w3, s3, b3, wp, sp, bp,
                      *, stride):
    y = scale_shift(conv2d(x, w1), s1, b1, relu=True)
    y = scale_shift(conv2d(y, w2, stride=stride), s2, b2, relu=True)
    y = scale_shift(conv2d(y, w3), s3, b3)
    sc = scale_shift(conv2d(x, wp, stride=stride), sp, bp)
    return jnp.maximum(y + sc, 0.0)


def _block_apply_id(x, w1, s1, b1, w2, s2, b2, w3, s3, b3):
    y = scale_shift(conv2d(x, w1), s1, b1, relu=True)
    y = scale_shift(conv2d(y, w2), s2, b2, relu=True)
    y = scale_shift(conv2d(y, w3), s3, b3)
    return jnp.maximum(y + x, 0.0)


def _classifier_apply(x, w, b):
    return linear(global_avgpool(x), w, b)


def _build_resnet(
    name: str, block_plan: list[int], spatial: int, num_classes: int,
    batch: int,
) -> ModelDef:
    if spatial % 32 != 0:
        raise ValueError(f"spatial must be a multiple of 32, got {spatial}")
    units: list[Unit] = []
    h = spatial // 4  # stem: /2 conv then /2 pool
    units.append(
        Unit(
            name="stem",
            kind="stem",
            apply=_stem_apply,
            param_shapes=[(7, 7, 3, 64), (64,), (64,)],
            in_shape=(batch, spatial, spatial, 3),
            out_shape=(batch, h, h, 64),
            flops=_conv_flops((batch, spatial // 2, spatial // 2, 64), 7, 7, 3),
        )
    )
    cin = 64
    stage_width = [64, 128, 256, 512]
    for si, nblocks in enumerate(block_plan):
        width = stage_width[si]
        cout = width * 4
        for bi in range(nblocks):
            stride = 2 if (si > 0 and bi == 0) else 1
            proj = bi == 0  # first block of every stage projects (or widens)
            h_out = h // stride
            shapes = [
                (1, 1, cin, width), (width,), (width,),
                (3, 3, width, width), (width,), (width,),
                (1, 1, width, cout), (cout,), (cout,),
            ]
            flops = (
                _conv_flops((batch, h, h, width), 1, 1, cin)
                + _conv_flops((batch, h_out, h_out, width), 3, 3, width)
                + _conv_flops((batch, h_out, h_out, cout), 1, 1, width)
            )
            if proj:
                shapes += [(1, 1, cin, cout), (cout,), (cout,)]
                flops += _conv_flops((batch, h_out, h_out, cout), 1, 1, cin)
                apply = functools.partial(_block_apply_proj, stride=stride)
            else:
                apply = _block_apply_id
            units.append(
                Unit(
                    name=f"b{si + 1}_{bi + 1}",
                    kind="block",
                    apply=apply,
                    param_shapes=shapes,
                    in_shape=(batch, h, h, cin),
                    out_shape=(batch, h_out, h_out, cout),
                    flops=flops,
                )
            )
            h, cin = h_out, cout
    units.append(
        Unit(
            name="classifier",
            kind="classifier",
            apply=_classifier_apply,
            param_shapes=[(cin, num_classes), (num_classes,)],
            in_shape=(batch, h, h, cin),
            out_shape=(batch, num_classes),
            flops=_dense_flops(batch, cin, num_classes),
        )
    )
    return ModelDef(name, (batch, spatial, spatial, 3), units)


def build_resnet50(spatial: int = 64, num_classes: int = 1000, batch: int = 1):
    """ResNet-50 as 18 units: stem + [3,4,6,3] bottleneck blocks + classifier."""
    return _build_resnet("resnet50", [3, 4, 6, 3], spatial, num_classes, batch)


def build_resnet152(spatial: int = 64, num_classes: int = 1000, batch: int = 1):
    """ResNet-152 as 52 units: stem + [3,8,36,3] blocks + classifier.

    52 units exactly matches the paper's "maximum number of pipeline stages
    ResNet152 could run with is 52".
    """
    return _build_resnet("resnet152", [3, 8, 36, 3], spatial, num_classes, batch)


BUILDERS = {
    "vgg16": build_vgg16,
    "resnet50": build_resnet50,
    "resnet152": build_resnet152,
}


def build(name: str, **kw) -> ModelDef:
    if name not in BUILDERS:
        raise KeyError(f"unknown model {name!r}; have {sorted(BUILDERS)}")
    return BUILDERS[name](**kw)
