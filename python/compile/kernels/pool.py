"""L1 Pallas kernel: max pooling.

Pooling is bandwidth- not FLOP-bound, so the TPU formulation keeps whole
spatial tiles resident in VMEM and reduces over the (kh, kw) window with
vector max ops — there is no MXU work here. One grid step per batch image;
channels stay vectorized on the last (lane) axis.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _maxpool_kernel(x_ref, o_ref, *, k: int, stride: int, ho: int, wo: int):
    x = x_ref[...]  # (1, h, w, c) block
    # Strided window max: unrolled over the k*k window offsets (k is tiny,
    # 2 or 3), each term a strided slice — pure VPU work, no gathers.
    acc = None
    for dy in range(k):
        for dx in range(k):
            sl = x[
                :,
                dy : dy + stride * ho : stride,
                dx : dx + stride * wo : stride,
                :,
            ]
            acc = sl if acc is None else jnp.maximum(acc, sl)
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("k", "stride", "interpret"))
def maxpool2d(
    x: jax.Array, *, k: int = 2, stride: int = 2, interpret: bool = True
) -> jax.Array:
    """VALID max-pool over NHWC with a k×k window."""
    if x.ndim != 4:
        raise ValueError(f"maxpool2d expects NHWC, got {x.shape}")
    n, h, w, c = x.shape
    ho = (h - k) // stride + 1
    wo = (w - k) // stride + 1
    kern = functools.partial(_maxpool_kernel, k=k, stride=stride, ho=ho, wo=wo)
    return pl.pallas_call(
        kern,
        grid=(n,),
        in_specs=[pl.BlockSpec((1, h, w, c), lambda i: (i, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, ho, wo, c), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, ho, wo, c), x.dtype),
        interpret=interpret,
    )(x)


def global_avgpool(x: jax.Array) -> jax.Array:
    """Global average pool NHWC -> (N, C). Reduction, left to XLA to fuse."""
    return jnp.mean(x, axis=(1, 2))
