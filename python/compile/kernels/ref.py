"""Pure-jnp correctness oracles for every Pallas kernel.

These are the ground truth the pytest/hypothesis suite checks the kernels
against (`assert_allclose`). No pallas imports here — plain jax.numpy and
lax reference semantics only.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    return jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32)).astype(x.dtype)


def linear_ref(x, w, b, *, relu: bool = False):
    y = matmul_ref(x, w) + b[None, :]
    return jnp.maximum(y, 0.0) if relu else y


def conv2d_ref(x, w, b=None, *, stride: int = 1, padding: str = "SAME",
               relu: bool = False):
    y = lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    if b is not None:
        y = y + b[None, None, None, :]
    return jnp.maximum(y, 0.0) if relu else y


def scale_shift_ref(x, scale, shift, *, relu: bool = False):
    y = x * scale[None, None, None, :] + shift[None, None, None, :]
    return jnp.maximum(y, 0.0) if relu else y


def maxpool2d_ref(x, *, k: int = 2, stride: int = 2):
    return lax.reduce_window(
        x,
        -jnp.inf,
        lax.max,
        window_dimensions=(1, k, k, 1),
        window_strides=(1, stride, stride, 1),
        padding="VALID",
    )


def global_avgpool_ref(x):
    return jnp.mean(x, axis=(1, 2))
