"""L1 Pallas kernels (build-time only; lowered into per-unit HLO)."""

from .conv2d import conv2d, scale_shift
from .matmul import linear, matmul
from .pool import global_avgpool, maxpool2d

__all__ = [
    "conv2d",
    "scale_shift",
    "matmul",
    "linear",
    "maxpool2d",
    "global_avgpool",
]
