"""L1 Pallas kernel: tiled matmul — the compute hot-spot of every CNN unit.

The convolution layers are lowered to im2col + this matmul (see conv2d.py),
so a single well-tuned contraction kernel carries the whole model, exactly
like the MXU systolic array would on a real TPU.

TPU mapping (DESIGN.md §Hardware-Adaptation):
  * grid = (M/bm, N/bn, K/bk) with K innermost, so A- and B-tiles stream
    through VMEM while the output tile stays resident and accumulates —
    the Pallas idiom for double-buffered MXU accumulation.
  * block sizes default to 128×128×128: (bm*bk + bk*bn + bm*bn) * 4 B
    ≈ 196 KiB of VMEM, far under the ~16 MiB budget, leaving headroom for
    double buffering.
  * `preferred_element_type=jnp.float32` keeps the accumulator in f32 even
    for bf16 inputs (MXU-native mixed precision).

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel is lowered to plain HLO for both the pytest
oracle checks and the rust serving runtime.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile shape (CPU-interpret path). The MXU systolic dimension is
# 128, so every dim stays a 128-multiple; bk/bn are much fatter than the
# square 128^3 tile because interpret-mode cost is dominated by grid-step
# count — EXPERIMENTS.md §Perf L1 logs the measured 31x end-to-end win.
# On a real TPU use (128, 512, 512): (bm*bk + bk*bn + bm*bn)*4B ≈ 1.6 MiB
# double-buffers comfortably inside the ~16 MiB VMEM budget, while the
# shipped CPU defaults (≈9.6 MiB) would not.
DEFAULT_BM = 128
DEFAULT_BN = 1024
DEFAULT_BK = 2048


def _matmul_kernel(x_ref, w_ref, o_ref, *, nk: int):
    """One (bm, bn) output tile; program_id(2) walks the K dimension.

    The output block index map ignores the K coordinate, so Pallas keeps the
    same o_ref block resident across all nk iterations — it is the f32
    accumulator.
    """
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def _pad_to(x: jax.Array, rows: int, cols: int) -> jax.Array:
    pr, pc = rows - x.shape[0], cols - x.shape[1]
    if pr == 0 and pc == 0:
        return x
    return jnp.pad(x, ((0, pr), (0, pc)))


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret")
)
def matmul(
    x: jax.Array,
    w: jax.Array,
    *,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
    interpret: bool = True,
) -> jax.Array:
    """`x @ w` via the Pallas tiled kernel.

    Arbitrary (M, K) x (K, N) shapes: inputs are zero-padded up to tile
    multiples and the result is sliced back. Accumulation is f32; the output
    dtype follows x.
    """
    if x.ndim != 2 or w.ndim != 2:
        raise ValueError(f"matmul expects 2-D operands, got {x.shape} @ {w.shape}")
    if x.shape[1] != w.shape[0]:
        raise ValueError(f"contraction mismatch: {x.shape} @ {w.shape}")
    m, k = x.shape
    _, n = w.shape
    # Shrink tiles for small operands so tiny layers don't pay 128x padding.
    bm = min(bm, _round_up(m, 8))
    bn = min(bn, _round_up(n, 8))
    bk = min(bk, _round_up(k, 8))
    mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)
    xp = _pad_to(x, mp, kp)
    wp = _pad_to(w, kp, np_)
    nk = kp // bk

    out = pl.pallas_call(
        functools.partial(_matmul_kernel, nk=nk),
        grid=(mp // bm, np_ // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(xp, wp)
    return out[:m, :n].astype(x.dtype)


def linear(
    x: jax.Array, w: jax.Array, b: jax.Array, *, relu: bool = False
) -> jax.Array:
    """Dense layer on the Pallas matmul: y = x @ w + b, optional ReLU."""
    y = matmul(x, w) + b[None, :]
    if relu:
        y = jnp.maximum(y, 0.0)
    return y
