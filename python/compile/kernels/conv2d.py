"""L1 Pallas kernel: conv2d as im2col + tiled MXU matmul.

The paper's pipeline stages are dominated by convolution layers; on TPU the
profitable formulation is not a thread-block direct convolution (the GPU
idiom) but an im2col gather feeding the MXU systolic array — see DESIGN.md
§Hardware-Adaptation. The gather is cheap data movement that XLA fuses; the
FLOPs all land in the Pallas matmul kernel (kernels/matmul.py).

All convs here are NHWC, stride `s`, SAME or VALID padding, fused optional
bias + ReLU (one lowered unit per layer keeps the HLO fusion-friendly,
DESIGN.md §Perf L2).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .matmul import matmul


def _im2col(
    x: jax.Array, kh: int, kw: int, stride: int, padding: str
) -> tuple[jax.Array, int, int]:
    """Extract (N*Ho*Wo, kh*kw*C) patches from NHWC input."""
    n, h, w, c = x.shape
    patches = lax.conv_general_dilated_patches(
        x,
        filter_shape=(kh, kw),
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    # conv_general_dilated_patches yields channels ordered as (C, kh, kw)
    # on the last axis; reorder to (kh, kw, C) to match HWIO weights.
    ho, wo = patches.shape[1], patches.shape[2]
    patches = patches.reshape(n, ho, wo, c, kh, kw)
    patches = patches.transpose(0, 1, 2, 4, 5, 3)
    return patches.reshape(n * ho * wo, kh * kw * c), ho, wo


def conv2d(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array | None = None,
    *,
    stride: int = 1,
    padding: str = "SAME",
    relu: bool = False,
) -> jax.Array:
    """2-D convolution via im2col + Pallas matmul.

    Args:
      x: NHWC input `(n, h, w, cin)`.
      w: HWIO weights `(kh, kw, cin, cout)`.
      b: optional `(cout,)` bias, fused.
      stride: spatial stride (same for h and w).
      padding: "SAME" or "VALID".
      relu: fuse a ReLU on the output.
    """
    if x.ndim != 4 or w.ndim != 4:
        raise ValueError(f"conv2d expects NHWC x and HWIO w, got {x.shape}, {w.shape}")
    if x.shape[3] != w.shape[2]:
        raise ValueError(f"channel mismatch: x {x.shape} vs w {w.shape}")
    n = x.shape[0]
    kh, kw, cin, cout = w.shape
    cols, ho, wo = _im2col(x, kh, kw, stride, padding)
    y = matmul(cols, w.reshape(kh * kw * cin, cout))
    y = y.reshape(n, ho, wo, cout)
    if b is not None:
        y = y + b[None, None, None, :]
    if relu:
        y = jnp.maximum(y, 0.0)
    return y


def scale_shift(
    x: jax.Array, scale: jax.Array, shift: jax.Array, *, relu: bool = False
) -> jax.Array:
    """Inference-time batch-norm: per-channel `x*scale + shift`.

    At inference BN folds to an affine transform of the conv output; keeping
    it a separate (scale, shift) pair rather than folding into the conv
    weights lets the rust runtime reuse one conv artifact across BN variants.
    """
    y = x * scale[None, None, None, :] + shift[None, None, None, :]
    if relu:
        y = jnp.maximum(y, 0.0)
    return y
