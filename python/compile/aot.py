"""AOT compile path: lower every model unit to HLO text + manifest.

This is the ONLY python entrypoint in the system; it runs once at build time
(`make artifacts`) and produces:

  artifacts/manifest.json                     — machine-readable index
  artifacts/<model>/uNN_<name>.hlo.txt        — one HLO module per unit
  artifacts/<model>/gold/uNN.{in,out,pK}.bin  — f32 LE gold tensors for
                                                small units (rust runtime
                                                integration tests)

HLO *text* is the interchange format, NOT `.serialize()`: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published `xla` 0.1.6 crate binds) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .model import ModelDef, Unit, build

# Units whose total tensor volume (input + output + params) is below this
# many f32 elements get gold files dumped for the rust integration tests.
GOLD_ELEM_BUDGET = 1_500_000


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the smoke-verified recipe)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_unit(unit: Unit) -> str:
    specs = [jax.ShapeDtypeStruct(unit.in_shape, jnp.float32)] + [
        jax.ShapeDtypeStruct(s, jnp.float32) for s in unit.param_shapes
    ]
    lowered = jax.jit(unit.apply).lower(*specs)
    return to_hlo_text(lowered)


def _dump_bin(path: str, arr: jax.Array) -> None:
    np.asarray(arr, dtype="<f4").tofile(path)


def export_model(
    model: ModelDef, out_dir: str, *, seed: int, gold: bool, verbose: bool
) -> dict:
    mdir = os.path.join(out_dir, model.name)
    gdir = os.path.join(mdir, "gold")
    os.makedirs(mdir, exist_ok=True)
    os.makedirs(gdir, exist_ok=True)

    params = model.init_params(seed)
    # Deterministic input for the gold chain.
    x = jax.random.uniform(
        jax.random.PRNGKey(seed), model.input_shape, jnp.float32
    )

    units_meta = []
    for ui, unit in enumerate(model.units):
        hlo_rel = f"{model.name}/u{ui:02d}_{unit.name}.hlo.txt"
        hlo_path = os.path.join(out_dir, hlo_rel)
        text = lower_unit(unit)
        with open(hlo_path, "w") as f:
            f.write(text)

        y = unit.apply(x, *params[ui])
        assert tuple(y.shape) == tuple(unit.out_shape), (
            f"{model.name}/{unit.name}: traced out shape {y.shape} "
            f"!= declared {unit.out_shape}"
        )

        volume = (
            int(np.prod(unit.in_shape))
            + int(np.prod(unit.out_shape))
            + sum(int(np.prod(s)) for s in unit.param_shapes)
        )
        gold_meta = None
        if gold and volume <= GOLD_ELEM_BUDGET:
            gin = f"{model.name}/gold/u{ui:02d}.in.bin"
            gout = f"{model.name}/gold/u{ui:02d}.out.bin"
            _dump_bin(os.path.join(out_dir, gin), x)
            _dump_bin(os.path.join(out_dir, gout), y)
            gps = []
            for pi, p in enumerate(params[ui]):
                gp = f"{model.name}/gold/u{ui:02d}.p{pi}.bin"
                _dump_bin(os.path.join(out_dir, gp), p)
                gps.append(gp)
            gold_meta = {"input": gin, "output": gout, "params": gps}

        units_meta.append(
            {
                "index": ui,
                "name": unit.name,
                "kind": unit.kind,
                "hlo": hlo_rel,
                "in_shape": list(unit.in_shape),
                "out_shape": list(unit.out_shape),
                "param_shapes": [list(s) for s in unit.param_shapes],
                "flops": unit.flops,
                "gold": gold_meta,
            }
        )
        if verbose:
            print(
                f"  [{model.name}] u{ui:02d} {unit.name:<12} "
                f"{len(text):>8} chars  flops={unit.flops:.3e}"
                + ("  +gold" if gold_meta else "")
            )
        x = y

    return {
        "name": model.name,
        "input_shape": list(model.input_shape),
        "num_units": model.num_units,
        "seed": seed,
        "units": units_meta,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="compile.aot", description=__doc__.splitlines()[0]
    )
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument(
        "--models",
        default="vgg16,resnet50",
        help="comma-separated: vgg16,resnet50,resnet152",
    )
    ap.add_argument("--spatial", type=int, default=64)
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-gold", action="store_true")
    ap.add_argument("-q", "--quiet", action="store_true")
    args = ap.parse_args(argv)

    os.makedirs(args.out, exist_ok=True)
    manifest = {
        "format": 1,
        "spatial": args.spatial,
        "batch": args.batch,
        "models": {},
    }
    for name in args.models.split(","):
        name = name.strip()
        if not name:
            continue
        model = build(name, spatial=args.spatial, batch=args.batch)
        print(f"lowering {name}: {model.num_units} units ...")
        manifest["models"][name] = export_model(
            model,
            args.out,
            seed=args.seed,
            gold=not args.no_gold,
            verbose=not args.quiet,
        )
    mpath = os.path.join(args.out, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {mpath}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
