"""Build-time compile path (L1 Pallas kernels + L2 JAX models + AOT).

Never imported at serving time — the rust binary only consumes the HLO text
artifacts this package emits.
"""
