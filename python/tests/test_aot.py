"""AOT path tests: HLO text emission, manifest schema, gold tensors.

These run the same lowering recipe `make artifacts` uses and parse the HLO
text the way the rust loader's XLA parser will (entry computation,
parameter count), so breakage shows up here before it hits rust.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.aot import export_model, lower_unit, to_hlo_text
from compile.model import build_vgg16, build_resnet50


@pytest.fixture(scope="module")
def tiny_vgg():
    return build_vgg16(spatial=32, num_classes=8, fc_dim=32)


def _entry_params(text: str) -> int:
    """Count parameters of the ENTRY computation only (nested called
    computations declare their own)."""
    lines = text.splitlines()
    start = next(i for i, l in enumerate(lines) if l.startswith("ENTRY"))
    n = 0
    for l in lines[start + 1 :]:
        if l.startswith("}"):
            break
        if "parameter(" in l:
            n += 1
    return n


def test_lower_unit_emits_hlo_text(tiny_vgg):
    text = lower_unit(tiny_vgg.units[0])
    assert "HloModule" in text
    assert "ENTRY" in text
    # one parameter per input: x + weight + bias
    assert _entry_params(text) == 3


def test_hlo_text_has_no_serialized_proto_markers(tiny_vgg):
    """Interchange must be text — a proto blob would break xla 0.5.1."""
    text = lower_unit(tiny_vgg.units[0])
    assert text.isprintable() or "\n" in text
    assert not text.startswith(b"\x08".decode("latin1"))


def test_lowered_unit_is_tuple_rooted(tiny_vgg):
    """return_tuple=True — the rust side unwraps with to_tuple1()."""
    text = lower_unit(tiny_vgg.units[-1])
    root_lines = [l for l in text.splitlines() if "ROOT" in l]
    assert any("tuple(" in l for l in root_lines)


def test_export_model_manifest_and_files(tiny_vgg, tmp_path):
    meta = export_model(
        tiny_vgg, str(tmp_path), seed=0, gold=True, verbose=False
    )
    assert meta["num_units"] == 16
    for u in meta["units"]:
        path = tmp_path / u["hlo"]
        assert path.exists(), u["hlo"]
        assert path.stat().st_size > 100
        assert u["flops"] > 0
        assert len(u["param_shapes"]) >= 2


def test_export_gold_roundtrip(tiny_vgg, tmp_path):
    """Gold tensors must reproduce the unit outputs exactly (bitwise f32)."""
    meta = export_model(
        tiny_vgg, str(tmp_path), seed=0, gold=True, verbose=False
    )
    checked = 0
    for u in meta["units"]:
        if u["gold"] is None:
            continue
        x = np.fromfile(tmp_path / u["gold"]["input"], "<f4").reshape(
            u["in_shape"]
        )
        params = [
            np.fromfile(tmp_path / p, "<f4").reshape(s)
            for p, s in zip(u["gold"]["params"], u["param_shapes"])
        ]
        want = np.fromfile(tmp_path / u["gold"]["output"], "<f4").reshape(
            u["out_shape"]
        )
        unit = tiny_vgg.units[u["index"]]
        got = np.asarray(unit.apply(jnp.asarray(x), *map(jnp.asarray, params)))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
        checked += 1
    assert checked >= 8  # most tiny-vgg units fit the gold budget


def test_export_manifest_json_serializable(tiny_vgg, tmp_path):
    meta = export_model(
        tiny_vgg, str(tmp_path), seed=3, gold=False, verbose=False
    )
    blob = json.dumps(meta)
    back = json.loads(blob)
    assert back["seed"] == 3
    assert all(u["gold"] is None for u in back["units"])


def test_resnet_units_lower(tmp_path):
    """Every distinct resnet unit kind lowers: stem, proj block, id block,
    classifier."""
    m = build_resnet50(spatial=32, num_classes=8)
    for idx in (0, 1, 2, 17):
        text = lower_unit(m.units[idx])
        assert "HloModule" in text
        nparams = 1 + len(m.units[idx].param_shapes)
        assert _entry_params(text) == nparams


def test_artifacts_dir_if_present_is_consistent():
    """If `make artifacts` has run, validate the real manifest."""
    root = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    mpath = os.path.join(root, "manifest.json")
    if not os.path.exists(mpath):
        pytest.skip("artifacts not built")
    with open(mpath) as f:
        manifest = json.load(f)
    assert manifest["format"] == 1
    for name, model in manifest["models"].items():
        assert model["num_units"] == len(model["units"])
        for u in model["units"]:
            assert os.path.exists(os.path.join(root, u["hlo"])), u["hlo"]
