"""L1 kernel correctness: Pallas kernels vs pure-jnp oracle.

hypothesis sweeps shapes/dtypes; every property asserts allclose against
ref.py. This is the core correctness signal for the compute layer — the
same lowered code the rust runtime executes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv2d, linear, matmul, maxpool2d, scale_shift
from compile.kernels import global_avgpool
from compile.kernels.ref import (
    conv2d_ref,
    global_avgpool_ref,
    linear_ref,
    matmul_ref,
    maxpool2d_ref,
    scale_shift_ref,
)

SETTINGS = dict(deadline=None, max_examples=20)


def _rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    m=st.integers(1, 200),
    k=st.integers(1, 200),
    n=st.integers(1, 200),
    seed=st.integers(0, 2**16),
)
def test_matmul_matches_ref(m, k, n, seed):
    x = _rand(seed, (m, k))
    w = _rand(seed + 1, (k, n))
    np.testing.assert_allclose(
        matmul(x, w), matmul_ref(x, w), rtol=1e-4, atol=1e-4
    )


@settings(**SETTINGS)
@given(
    m=st.integers(1, 64),
    k=st.integers(1, 64),
    n=st.integers(1, 64),
    bm=st.sampled_from([8, 16, 32, 128]),
    bn=st.sampled_from([8, 16, 32, 128]),
    bk=st.sampled_from([8, 16, 32, 128]),
)
def test_matmul_block_shape_invariance(m, k, n, bm, bn, bk):
    """Result must not depend on the tiling — only on the operands."""
    x = _rand(7, (m, k))
    w = _rand(8, (k, n))
    got = matmul(x, w, bm=bm, bn=bn, bk=bk)
    np.testing.assert_allclose(got, matmul_ref(x, w), rtol=1e-4, atol=1e-4)


def test_matmul_bf16_inputs_f32_accumulate():
    x = _rand(3, (64, 512), jnp.bfloat16)
    w = _rand(4, (512, 64), jnp.bfloat16)
    got = matmul(x, w).astype(jnp.float32)
    want = jnp.dot(
        x.astype(jnp.float32), w.astype(jnp.float32)
    )
    # bf16 inputs, f32 accumulation: tolerance set by input rounding only.
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-1)


def test_matmul_rejects_bad_shapes():
    with pytest.raises(ValueError):
        matmul(jnp.zeros((2, 3)), jnp.zeros((4, 5)))
    with pytest.raises(ValueError):
        matmul(jnp.zeros((2, 3, 4)), jnp.zeros((4, 5)))


def test_matmul_identity():
    x = _rand(11, (32, 32))
    np.testing.assert_allclose(
        matmul(x, jnp.eye(32)), x, rtol=1e-5, atol=1e-5
    )


@settings(**SETTINGS)
@given(
    m=st.integers(1, 48),
    k=st.integers(1, 48),
    n=st.integers(1, 48),
    relu=st.booleans(),
)
def test_linear_matches_ref(m, k, n, relu):
    x = _rand(1, (m, k))
    w = _rand(2, (k, n))
    b = _rand(3, (n,))
    np.testing.assert_allclose(
        linear(x, w, b, relu=relu),
        linear_ref(x, w, b, relu=relu),
        rtol=1e-4,
        atol=1e-4,
    )


def test_linear_relu_clamps_negative():
    x = -jnp.ones((4, 8))
    w = jnp.eye(8)
    b = jnp.zeros((8,))
    assert (linear(x, w, b, relu=True) == 0.0).all()


# ---------------------------------------------------------------------------
# conv2d
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    h=st.integers(4, 24),
    cin=st.integers(1, 8),
    cout=st.integers(1, 16),
    kk=st.sampled_from([1, 3, 5]),
    stride=st.sampled_from([1, 2]),
    padding=st.sampled_from(["SAME", "VALID"]),
    relu=st.booleans(),
)
def test_conv2d_matches_ref(h, cin, cout, kk, stride, padding, relu):
    if padding == "VALID" and h < kk:
        return
    x = _rand(1, (1, h, h, cin))
    w = _rand(2, (kk, kk, cin, cout))
    b = _rand(3, (cout,))
    np.testing.assert_allclose(
        conv2d(x, w, b, stride=stride, padding=padding, relu=relu),
        conv2d_ref(x, w, b, stride=stride, padding=padding, relu=relu),
        rtol=1e-3,
        atol=1e-3,
    )


@settings(**SETTINGS)
@given(n=st.integers(1, 3), h=st.sampled_from([8, 16]), seed=st.integers(0, 99))
def test_conv2d_batched(n, h, seed):
    x = _rand(seed, (n, h, h, 3))
    w = _rand(seed + 1, (3, 3, 3, 4))
    np.testing.assert_allclose(
        conv2d(x, w), conv2d_ref(x, w), rtol=1e-3, atol=1e-3
    )


def test_conv2d_1x1_equals_pointwise_matmul():
    """A 1x1 conv is exactly a per-pixel matmul — cross-kernel consistency."""
    x = _rand(5, (1, 8, 8, 16))
    w = _rand(6, (1, 1, 16, 32))
    got = conv2d(x, w)
    want = matmul_ref(x.reshape(64, 16), w.reshape(16, 32)).reshape(1, 8, 8, 32)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_conv2d_rejects_mismatched_channels():
    with pytest.raises(ValueError):
        conv2d(jnp.zeros((1, 8, 8, 3)), jnp.zeros((3, 3, 4, 8)))


def test_conv2d_same_padding_preserves_spatial():
    x = _rand(1, (1, 13, 13, 2))
    w = _rand(2, (3, 3, 2, 5))
    assert conv2d(x, w).shape == (1, 13, 13, 5)


# ---------------------------------------------------------------------------
# scale_shift (inference BN)
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(h=st.integers(1, 16), c=st.integers(1, 16), relu=st.booleans())
def test_scale_shift_matches_ref(h, c, relu):
    x = _rand(1, (1, h, h, c))
    s = _rand(2, (c,))
    t = _rand(3, (c,))
    np.testing.assert_allclose(
        scale_shift(x, s, t, relu=relu),
        scale_shift_ref(x, s, t, relu=relu),
        rtol=1e-5,
        atol=1e-5,
    )


def test_scale_shift_identity():
    x = _rand(9, (1, 4, 4, 8))
    np.testing.assert_allclose(
        scale_shift(x, jnp.ones(8), jnp.zeros(8)), x, rtol=0, atol=0
    )


# ---------------------------------------------------------------------------
# pooling
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    n=st.integers(1, 2),
    h=st.integers(4, 32),
    c=st.integers(1, 8),
    k=st.sampled_from([2, 3]),
    stride=st.sampled_from([1, 2, 3]),
)
def test_maxpool_matches_ref(n, h, c, k, stride):
    if h < k:
        return
    x = _rand(1, (n, h, h, c))
    np.testing.assert_allclose(
        maxpool2d(x, k=k, stride=stride),
        maxpool2d_ref(x, k=k, stride=stride),
        rtol=0,
        atol=0,
    )


def test_maxpool_on_constant_is_constant():
    x = jnp.full((1, 8, 8, 4), 3.5)
    assert (maxpool2d(x) == 3.5).all()


def test_maxpool_picks_single_max():
    x = jnp.zeros((1, 4, 4, 1)).at[0, 1, 1, 0].set(9.0)
    y = maxpool2d(x, k=2, stride=2)
    assert y[0, 0, 0, 0] == 9.0


def test_global_avgpool_matches_ref():
    x = _rand(2, (2, 7, 7, 5))
    np.testing.assert_allclose(
        global_avgpool(x), global_avgpool_ref(x), rtol=1e-6, atol=1e-6
    )
