"""L2 model tests: unit chaining, shapes, parameter specs, FLOP accounting."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import build, build_resnet50, build_resnet152, build_vgg16
from compile.kernels.ref import conv2d_ref, linear_ref, maxpool2d_ref


# --------------------------------------------------------------------------
# structure
# --------------------------------------------------------------------------


def test_vgg16_has_16_units():
    assert build_vgg16(spatial=32).num_units == 16


def test_resnet50_has_18_units():
    assert build_resnet50(spatial=32).num_units == 18


def test_resnet152_has_52_units():
    """Paper: 'the maximum number of pipeline stages ResNet152 could run
    with is 52' — stem + 50 blocks + classifier."""
    assert build_resnet152(spatial=32).num_units == 52


def test_unknown_model_rejected():
    with pytest.raises(KeyError):
        build("alexnet")


def test_bad_spatial_rejected():
    with pytest.raises(ValueError):
        build_vgg16(spatial=50)


@pytest.mark.parametrize("name", ["vgg16", "resnet50", "resnet152"])
def test_unit_shapes_chain(name):
    """out_shape of unit i must equal in_shape of unit i+1 (dense flatten
    units declare the pre-flatten shape)."""
    m = build(name, spatial=32)
    for a, b in zip(m.units[:-1], m.units[1:]):
        assert int(np.prod(a.out_shape)) == int(np.prod(b.in_shape)), (
            f"{name}: {a.name} -> {b.name}"
        )


@pytest.mark.parametrize("name", ["vgg16", "resnet50", "resnet152"])
def test_flops_positive_and_plausible(name):
    m = build(name, spatial=32)
    total = sum(u.flops for u in m.units)
    assert all(u.flops > 0 for u in m.units)
    # sanity band: 1e7 .. 1e12 FLOPs per inference at 32x32
    assert 1e7 < total < 1e12


def test_vgg16_spatial_scales_flops():
    f32 = sum(u.flops for u in build_vgg16(spatial=32).units)
    f64 = sum(u.flops for u in build_vgg16(spatial=64).units)
    assert f64 > 3 * f32  # conv flops scale ~4x with spatial area


# --------------------------------------------------------------------------
# numerics: chained units == reference networks
# --------------------------------------------------------------------------


def test_vgg16_forward_matches_ref_chain():
    """Chain the model's own units and an independently-written ref chain."""
    m = build_vgg16(spatial=32, num_classes=10, fc_dim=64)
    params = m.init_params(seed=1)
    x = jax.random.uniform(jax.random.PRNGKey(42), m.input_shape)
    got = m.forward(x, params)

    # independent reference: hand-rolled VGG on ref kernels
    y = x
    for u, p in zip(m.units, params):
        if u.kind in ("conv", "conv_pool"):
            y = conv2d_ref(y, p[0], p[1], relu=True)
            if u.kind == "conv_pool":
                y = maxpool2d_ref(y)
        else:
            y = y.reshape(y.shape[0], -1) if y.ndim == 4 else y
            y = linear_ref(y, p[0], p[1], relu=(u.name != "fc3"))
    np.testing.assert_allclose(got, y, rtol=1e-3, atol=1e-3)
    assert got.shape == (1, 10)


def test_resnet50_forward_shape_and_finite():
    m = build_resnet50(spatial=32, num_classes=10)
    params = m.init_params(seed=2)
    x = jax.random.uniform(jax.random.PRNGKey(0), m.input_shape)
    y = m.forward(x, params)
    assert y.shape == (1, 10)
    assert bool(jnp.isfinite(y).all())


def test_resnet_block_identity_skip():
    """With all-zero conv weights an identity block must return relu(x)."""
    m = build_resnet50(spatial=32)
    blk = m.units[2]  # b1_2, identity block
    assert blk.kind == "block" and len(blk.param_shapes) == 9
    x = jax.random.normal(jax.random.PRNGKey(3), blk.in_shape)
    zeros = [jnp.zeros(s) for s in blk.param_shapes]
    y = blk.apply(x, *zeros)
    np.testing.assert_allclose(y, jnp.maximum(x, 0.0), rtol=0, atol=0)


def test_init_params_deterministic():
    m = build_vgg16(spatial=32)
    a = m.init_params(seed=5)
    b = m.init_params(seed=5)
    for pa, pb in zip(a, b):
        for x, y in zip(pa, pb):
            np.testing.assert_array_equal(x, y)


def test_init_params_bn_scales_are_one():
    m = build_resnet50(spatial=32)
    params = m.init_params(seed=0)
    stem = params[0]
    np.testing.assert_array_equal(stem[1], jnp.ones_like(stem[1]))  # scale
    np.testing.assert_array_equal(stem[2], jnp.zeros_like(stem[2]))  # shift


def test_batch_dimension_respected():
    m = build_vgg16(spatial=32, batch=2)
    assert m.input_shape[0] == 2
    assert all(u.in_shape[0] == 2 for u in m.units)
